//! The `UmRuntime` facade: state, constructor, allocation API, and the
//! GPU-side access entry point. Mechanism-specific methods live in the
//! sibling files (`fault`, `migrate`, `advise`, `prefetch`, `evict`,
//! `host`), all as `impl UmRuntime` blocks.

use crate::gpu::stream::StreamId;
use crate::mem::{
    AllocId, AllocKind, ChunkRef, DeviceMemory, ManagedSpace, PageRange, PageState,
    Residency, TransferMode, PAGES_PER_CHUNK, PAGE_SIZE,
};
use crate::mem::page::{AdviseFlags, PageFlags};
use crate::platform::PlatformSpec;
use crate::sim::{BandwidthResource, Injector, SerialResource};
use crate::trace::{Decision, ReasonCode, Rung, Trace, TraceKind};
use crate::util::units::{transfer_ns, Bytes, Ns};

use super::auto::AutoEngine;
use super::metrics::UmMetrics;
use super::policy::UmPolicy;

/// Result of one (host or GPU) access through the UM runtime.
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct AccessOutcome {
    /// Simulated time at which the access's data is fully available.
    pub done: Ns,
    /// Fault-handling time the accessor stalled on.
    pub fault_stall: Ns,
    /// Migration wait beyond the fault service (transfer tail).
    pub transfer_wait: Ns,
    /// Bytes this access must pull over the link *during execution*
    /// (remote/zero-copy reads or writes; a recurring per-access cost).
    pub remote_bytes: Bytes,
    /// Bytes migrated H2D / D2H by this access.
    pub h2d_bytes: Bytes,
    pub d2h_bytes: Bytes,
}

impl AccessOutcome {
    pub fn merge(&mut self, other: AccessOutcome) {
        self.done = self.done.max(other.done);
        self.fault_stall += other.fault_stall;
        self.transfer_wait += other.transfer_wait;
        self.remote_bytes += other.remote_bytes;
        self.h2d_bytes += other.h2d_bytes;
        self.d2h_bytes += other.d2h_bytes;
    }
}

/// Classification of a page for run-splitting (all fields participate in
/// equality so runs are homogeneous in every dimension that matters).
#[derive(Clone, Copy, Debug, PartialEq)]
pub(super) struct Class {
    pub res: Residency,
    pub read_mostly: bool,
    pub pref_gpu: bool,
    pub pref_host: bool,
    pub accessed_by_cpu: bool,
    pub gpu_mapped: bool,
    pub cpu_mapped: bool,
}

pub(super) fn classify(p: &crate::mem::PageState) -> Class {
    Class {
        res: p.residency,
        read_mostly: p.advise.read_mostly(),
        pref_gpu: p.advise.preferred_gpu(),
        pref_host: p.advise.preferred_host(),
        accessed_by_cpu: p.advise.get(AdviseFlags::ACCESSED_BY_CPU),
        gpu_mapped: p.flags.get(PageFlags::GPU_MAPPED),
        cpu_mapped: p.flags.get(PageFlags::CPU_MAPPED),
    }
}

/// The Unified Memory runtime simulator.
pub struct UmRuntime {
    pub plat: PlatformSpec,
    pub policy: UmPolicy,
    pub space: ManagedSpace,
    pub dev: DeviceMemory,
    /// DMA engines, one per direction (CUDA UM uses dedicated copy
    /// engines; transfers in opposite directions overlap).
    pub dma_h2d: BandwidthResource,
    pub dma_d2h: BandwidthResource,
    /// The driver's serialized fault-handling path.
    pub fault_path: SerialResource,
    pub metrics: UmMetrics,
    pub trace: Trace,
    /// Set once any locality advise (`ReadMostly` /
    /// `PreferredLocation(Gpu)`) is applied. Placement hints override
    /// the driver's heuristic remote-overflow behaviour on coherent
    /// platforms: the driver then strictly honors locality by
    /// migrate+evict, which under oversubscription produces the P9
    /// pathology the paper reports (§IV-B; DESIGN.md §1).
    pub advise_hints_active: bool,
    /// Eviction bytes charged to the GPU access currently being
    /// serviced (reset at each `gpu_access`); drives the ETC-throttle
    /// ablation ([10]).
    pub(super) access_evicted_bytes: Bytes,
    /// The stream whose access is currently being serviced — set at
    /// every `gpu_access_on` / `host_access_on` entry and read by the
    /// down-path mechanisms (fault servicing, engine actuation) so
    /// per-stream attribution threads through the whole fault/
    /// migration path without widening every internal signature.
    pub(super) access_stream: StreamId,
    /// The online policy engine (`um::auto`), attached only for the
    /// `UM Auto` variant via [`UmRuntime::enable_auto`]. `None` leaves
    /// every other variant's behaviour bit-identical to before.
    pub(super) auto: Option<AutoEngine>,
    /// Engine eviction hints (the `--evictor learned` seam into
    /// `um/evict.rs`). Empty unless the engine's dead-range ranker has
    /// produced a confident forecast; ignored entirely by the LRU
    /// evictor.
    pub(super) evict_hints: super::evict::AutoEvictHints,
    /// Outstanding eviction audit: pages evicted (or early-dropped)
    /// this run and not yet re-demanded, one bit per page of the
    /// 32-page chunk. Page-accurate so touching the still-resident
    /// part of a partially evicted chunk is never mischarged. Pure
    /// bookkeeping for the eviction-quality counters — never consulted
    /// by any policy.
    pub(super) evict_audit: crate::util::fxhash::FxHashMap<ChunkRef, u32>,
    /// Predicted-live victims parked by the learned evictor, in their
    /// original LRU order. Persisted across `ensure_device_space`
    /// calls so each live chunk is deferred once per hint refresh;
    /// flushed back into the LRU when hints refresh. Always empty
    /// under the LRU evictor.
    pub(super) evict_deferred: std::collections::VecDeque<ChunkRef>,
    /// Fault-injection state (`sim/inject.rs`); `None` when the
    /// policy's chaos scenario is `Off` — every hook then reduces to a
    /// tag check and the runtime is byte-identical to the
    /// un-instrumented behaviour (pinned by
    /// `rust/tests/chaos_determinism.rs`). Rebuilt by
    /// [`UmRuntime::reset_run_state`] so every repetition replays the
    /// same perturbation schedule.
    pub(super) inject: Option<Injector>,
    /// Bulk-prefetch pieces that failed transiently under injection
    /// (the flaky-prefetch scenario), awaiting the `um::auto`
    /// watchdog's bounded retry — or a plain demand fault, whichever
    /// touches them first.
    pub(super) failed_prefetches: std::collections::VecDeque<(AllocId, PageRange)>,
    /// Whether the last chaos check saw a degraded link — provenance
    /// emits one `chaos.link_degrade` decision per episode edge, not
    /// one per access inside it. Pure trace bookkeeping.
    chaos_link_degraded: bool,
    /// Per-(allocation, counter-group) remote-access touch counters on
    /// the coherent platform — the hardware access counters that replace
    /// the fault stream as the placement signal (`docs/PLATFORMS.md`).
    /// Key is (alloc, group index), a group spanning
    /// `policy.counter_group_pages` pages; a coherent-serviced run bumps
    /// each overlapping group once. Always empty unless
    /// `policy.coherent`; cleared by [`UmRuntime::reset_run_state`].
    pub(super) counter_touches: crate::util::fxhash::FxHashMap<(AllocId, u32), u32>,
    /// Per-allocation access-counter threshold overrides issued by the
    /// `um::auto` engine on the coherent platform — its degraded form
    /// of stream escalation (there is no fault stream to escalate and
    /// no bulk prefetch to issue; the engine tunes *when* the hardware
    /// migrates instead). Empty unless `UM Auto` on a coherent
    /// platform; an inert watchdog withdraws the entries. A base
    /// `counter_threshold` of 0 (migration disabled) is never
    /// overridden.
    pub(super) counter_threshold_hints: crate::util::fxhash::FxHashMap<AllocId, u32>,
    /// Remote traffic avoided by counter placement: bytes of device-run
    /// hits on `COUNTER_PLACED` pages since the engine's last ledger
    /// tick. Drained by `auto_post_access` into the watchdog's benefit
    /// column — the coherent analogue of consumed-prefetch bytes. Pure
    /// bookkeeping; never consulted by placement policy.
    pub(super) coherent_avoided_remote: Bytes,
}

impl UmRuntime {
    pub fn new(plat: &PlatformSpec) -> UmRuntime {
        Self::with_policy(plat, plat.um)
    }

    /// Override the platform's default driver policy (ablations).
    pub fn with_policy(plat: &PlatformSpec, policy: UmPolicy) -> UmRuntime {
        policy.validate().expect("invalid UM policy");
        let link = plat.link;
        UmRuntime {
            plat: *plat,
            policy,
            space: ManagedSpace::new(),
            dev: DeviceMemory::new(plat.gpu.usable()),
            dma_h2d: BandwidthResource::new("dma_h2d", link.peak_bw, link.latency),
            dma_d2h: BandwidthResource::new("dma_d2h", link.peak_bw, link.latency),
            fault_path: SerialResource::new("fault_path"),
            metrics: UmMetrics::default(),
            trace: Trace::disabled(),
            advise_hints_active: false,
            access_evicted_bytes: 0,
            access_stream: StreamId::DEFAULT,
            auto: None,
            evict_hints: super::evict::AutoEvictHints::default(),
            evict_audit: crate::util::fxhash::FxHashMap::default(),
            evict_deferred: std::collections::VecDeque::new(),
            inject: Injector::new(policy.inject),
            failed_prefetches: std::collections::VecDeque::new(),
            chaos_link_degraded: false,
            counter_touches: crate::util::fxhash::FxHashMap::default(),
            counter_threshold_hints: crate::util::fxhash::FxHashMap::default(),
            coherent_avoided_remote: 0,
        }
    }

    /// The watchdog rung decisions are stamped with — [`Rung::Full`]
    /// when no engine is attached (plain variants never degrade).
    pub(super) fn current_rung(&self) -> Rung {
        match &self.auto {
            Some(e) => e.watchdog.mode().rung(),
            None => Rung::Full,
        }
    }

    pub fn enable_trace(&mut self) {
        self.trace = Trace::enabled();
    }

    // ---------------------------------------------------------------
    // Allocation API
    // ---------------------------------------------------------------

    /// `cudaMallocManaged`.
    pub fn malloc_managed(&mut self, name: &str, size: Bytes) -> AllocId {
        self.space.alloc(name, size, AllocKind::Managed)
    }

    /// `cudaMalloc` (explicit variant; always device-resident, counted
    /// against device capacity immediately).
    pub fn malloc_device(&mut self, name: &str, size: Bytes) -> AllocId {
        let id = self.space.alloc(name, size, AllocKind::Device);
        // Device allocations are physically backed at once.
        let alloc = self.space.get(id);
        let n_pages = alloc.n_pages();
        for chunk in 0..n_pages.div_ceil(PAGES_PER_CHUNK) {
            let pages_in_chunk =
                (n_pages - chunk * PAGES_PER_CHUNK).min(PAGES_PER_CHUNK);
            self.dev.add_resident(
                ChunkRef { alloc: id, chunk },
                pages_in_chunk as u64 * PAGE_SIZE,
                Ns::ZERO,
            );
            // cudaMalloc memory never migrates nor evicts: lock it.
            self.dev.set_locked(ChunkRef { alloc: id, chunk }, true);
        }
        let st = PageState {
            residency: Residency::Device,
            flags: PageFlags(PageFlags::POPULATED),
            ..Default::default()
        };
        self.space.get_mut(id).pages.set_range(PageRange::new(0, n_pages), st);
        id
    }

    /// Pageable host allocation (explicit variant source/destination).
    pub fn malloc_host(&mut self, name: &str, size: Bytes) -> AllocId {
        let id = self.space.alloc(name, size, AllocKind::Host);
        let n = self.space.get(id).n_pages();
        let st = PageState {
            residency: Residency::Host,
            flags: PageFlags(PageFlags::POPULATED),
            ..Default::default()
        };
        self.space.get_mut(id).pages.set_range(PageRange::new(0, n), st);
        id
    }

    // ---------------------------------------------------------------
    // Explicit copies (non-UM variant)
    // ---------------------------------------------------------------

    /// `cudaMemcpy(dst_device, src_host)`: bulk transfer; returns
    /// completion time. Not part of kernel execution time (the paper's
    /// figure of merit), but traced.
    pub fn memcpy_h2d(&mut self, dst: AllocId, bytes: Bytes, now: Ns) -> Ns {
        debug_assert_eq!(self.space.get(dst).kind, AllocKind::Device);
        let occ = self.dma_h2d.transfer(now, bytes, self.eff_at(TransferMode::Bulk, now));
        self.metrics.h2d_bytes += bytes;
        self.metrics.h2d_time += occ.duration();
        self.metrics.transfer_size.record(bytes);
        self.trace.record(TraceKind::MemcpyHtoD, occ.start, occ.end, bytes, Some(dst), "cudaMemcpy");
        occ.end
    }

    /// `cudaMemcpy(dst_host, src_device)`.
    pub fn memcpy_d2h(&mut self, src: AllocId, bytes: Bytes, now: Ns) -> Ns {
        debug_assert_eq!(self.space.get(src).kind, AllocKind::Device);
        let occ = self.dma_d2h.transfer(now, bytes, self.eff_at(TransferMode::Bulk, now));
        self.metrics.d2h_bytes += bytes;
        self.metrics.d2h_time += occ.duration();
        self.metrics.transfer_size.record(bytes);
        self.trace.record(TraceKind::MemcpyDtoH, occ.start, occ.end, bytes, Some(src), "cudaMemcpy");
        occ.end
    }

    // ---------------------------------------------------------------
    // GPU-side access (the kernel hot path)
    // ---------------------------------------------------------------

    /// A GPU kernel touches `range` of `id` at time `now` on the
    /// default stream. See [`UmRuntime::gpu_access_on`].
    pub fn gpu_access(&mut self, id: AllocId, range: PageRange, write: bool, now: Ns) -> AccessOutcome {
        self.gpu_access_on(StreamId::DEFAULT, id, range, write, now)
    }

    /// A GPU kernel on `stream` touches `range` of `id` at time `now`.
    /// Resolves faults/migrations/remote mappings and returns when the
    /// data is available plus the stall breakdown. `write` marks pages
    /// dirty and collapses ReadMostly duplicates. The originating
    /// stream keys the `um::auto` engine's observer/predictor state, so
    /// concurrent streams with different patterns on the same buffer
    /// never pollute each other's windows.
    pub fn gpu_access_on(
        &mut self,
        stream: StreamId,
        id: AllocId,
        range: PageRange,
        write: bool,
        now: Ns,
    ) -> AccessOutcome {
        let alloc = self.space.get(id);
        if alloc.kind != AllocKind::Managed {
            // cudaMalloc memory: always resident, no UM involvement.
            return AccessOutcome { done: now, ..Default::default() };
        }
        let range = alloc.pages.clamp(range);
        self.access_evicted_bytes = 0;
        self.access_stream = stream;
        self.metrics.stream_mut(stream).gpu_accesses += 1;
        // Streams are registered at access *entry*, so in-access
        // actuation (escalation sizing) already knows when a second
        // stream has entered the picture.
        if let Some(eng) = &mut self.auto {
            eng.note_stream(stream);
        }

        // Chaos layer (`sim/inject.rs`): ECC-style chunk retirement and
        // spurious fault noise fire at access entry — ahead of the
        // prefetch gate and the engine's observer tap, so every variant
        // sees the same perturbation stream and guardrail comparisons
        // under injection stay like-for-like.
        let now = if self.inject.is_some() { self.chaos_on_access(id, now) } else { now };

        // An in-flight auto-prefetch covering this range gates the
        // access (§III-A3: the wait for predicted-ahead data lands in
        // the measured kernel window, like a background prefetch). The
        // wait is attributed to `transfer_wait` so stall breakdowns
        // still sum to the measured window. The gate is the merge view
        // over *all* streams' outstanding predictions — an in-flight
        // transfer gates whoever touches its pages — and it is applied
        // *before* `auto_post_access` retires the pending entry, so a
        // consumed prediction is always waited for (see the pinning
        // test in `um::auto::actuator`).
        let gate_wait = match &self.auto {
            Some(eng) => eng.gate_for(id, range).saturating_sub(now),
            None => Ns::ZERO,
        };
        let now = now + gate_wait;

        // Incremental run-splitting: classification happens *as the
        // access proceeds*, because servicing an earlier run can evict
        // pages of a later run of the same access (cyclic thrashing
        // under oversubscription does exactly this).
        let mut out =
            AccessOutcome { done: now, transfer_wait: gate_wait, ..Default::default() };
        let mut ready = now;
        let mut pos = range.start;
        while pos < range.end {
            let (run, class) = self.next_run(id, pos, range.end);
            let o = self.gpu_access_run(stream, id, run, class, write, ready);
            // The driver handles this access's fault groups in order;
            // later runs queue behind earlier ones.
            ready = ready.max(o.done);
            out.merge(o);
            pos = run.end;
        }
        out.done = ready;
        // Closed loop: let the policy engine observe the completed
        // access and actuate (prefetch / advise / eviction hints).
        if self.auto.is_some() {
            self.auto_post_access(stream, id, range, write, &out);
        }
        out
    }

    /// The maximal homogeneous run starting at `pos` (fresh state).
    ///
    /// Hot path (§Perf): the interval table extends the run segment by
    /// segment — O(segments in the run), never per page — comparing a
    /// packed key (one u32 of residency + advise bits + mapping flags);
    /// the full [`Class`] is materialized once per run.
    pub(super) fn next_run(&self, id: AllocId, pos: u32, limit: u32) -> (PageRange, Class) {
        #[inline(always)]
        fn key(p: &PageState) -> u32 {
            // Residency, all advise bits, and the two mapping flags —
            // exactly the fields `classify` reads.
            let mapping = p.flags.0 & (PageFlags::GPU_MAPPED | PageFlags::CPU_MAPPED);
            (p.residency as u32) | ((p.advise.0 as u32) << 8) | ((mapping as u32) << 16)
        }
        let pages = &self.space.get(id).pages;
        let (run, state) = pages.run_at(pos, limit, key);
        (run, classify(state))
    }

    /// Handle one homogeneous run. Dispatches to the mechanism modules.
    fn gpu_access_run(
        &mut self,
        stream: StreamId,
        id: AllocId,
        run: PageRange,
        class: Class,
        write: bool,
        now: Ns,
    ) -> AccessOutcome {
        // Eviction audit: the GPU *demanding* pages of a chunk evicted
        // earlier this run means the eviction was wrong — whether the
        // demand is served by re-migration, a remote mapping, or data a
        // prefetch happened to bring back. Charged here (the demand
        // point) rather than at re-residency so speculative
        // prefetch-back alone never biases the eviction-quality
        // comparison. Pure bookkeeping; never alters behaviour.
        self.audit_note_demand(id, run, now);
        match class.res {
            Residency::Device => {
                self.touch_chunks(id, run, now);
                if self.policy.coherent {
                    // Device hits on counter-placed pages are the
                    // counter path's payoff: this traffic would have
                    // crossed the C2C link remotely had the group not
                    // migrated. Feeds the watchdog's benefit ledger.
                    let placed = self
                        .space
                        .get(id)
                        .pages
                        .count(run, |p| p.flags.get(PageFlags::COUNTER_PLACED));
                    self.coherent_avoided_remote += placed as u64 * PAGE_SIZE;
                }
                if write {
                    self.mark_dirty(id, run);
                }
                AccessOutcome { done: now, ..Default::default() }
            }
            Residency::Both => {
                self.touch_chunks(id, run, now);
                if write {
                    // Collapse ReadMostly duplicates (invalidation).
                    self.invalidate_duplicates(id, run, now)
                } else {
                    AccessOutcome { done: now, ..Default::default() }
                }
            }
            Residency::Unmapped => self.populate_on_device(id, run, write, now),
            Residency::Host => {
                if self.policy.coherent && !class.pref_gpu {
                    // Hardware-coherent platform: host-resident pages
                    // are serviced remotely at line granularity — no
                    // fault groups — while the access counters decide
                    // migration in the background (`um/migrate.rs`).
                    // Only an explicit `PreferredLocation(Gpu)` advise
                    // still forces an up-front migration.
                    self.coherent_access_host(id, run, class, write, now)
                } else if class.gpu_mapped || (class.pref_host && self.plat.gpu_can_access_host) {
                    // Established (or establishable) remote mapping:
                    // access host memory in place, no migration.
                    self.remote_access_host(id, run, now)
                } else if self.auto.is_some() {
                    // Policy engine attached: probe + bulk-escalate
                    // large streaming runs (um::auto).
                    self.auto_migrate_h2d(stream, id, run, class, write, now)
                } else {
                    self.migrate_or_map_h2d(id, run, class, write, now)
                }
            }
        }
    }

    // ---------------------------------------------------------------
    // Shared helpers used by the mechanism modules
    // ---------------------------------------------------------------

    pub(super) fn chunk_of(page: u32) -> u32 {
        page / PAGES_PER_CHUNK
    }

    /// Refresh the LRU position of every chunk overlapping `run`
    /// (batched: one [`DeviceMemory::touch_range`] call per run).
    pub(super) fn touch_chunks(&mut self, id: AllocId, run: PageRange, now: Ns) {
        let first = Self::chunk_of(run.start);
        let last = Self::chunk_of(run.end.saturating_sub(1).max(run.start));
        self.dev.touch_range(id, first, last, now);
    }

    pub(super) fn mark_dirty(&mut self, id: AllocId, run: PageRange) {
        self.space.get_mut(id).pages.update(run, |p| p.flags.set(PageFlags::DIRTY, true));
    }

    /// Register `run`'s pages as device-resident (LRU + accounting).
    /// `pinned` pins the covered chunks (PreferredLocation=GPU).
    pub(super) fn add_device_residency(&mut self, id: AllocId, run: PageRange, pinned: bool, now: Ns) {
        let mut page = run.start;
        while page < run.end {
            let chunk = Self::chunk_of(page);
            let chunk_end = ((chunk + 1) * PAGES_PER_CHUNK).min(run.end);
            let pages_here = chunk_end - page;
            let cref = ChunkRef { alloc: id, chunk };
            self.dev.add_resident(cref, pages_here as u64 * PAGE_SIZE, now);
            if pinned {
                self.dev.set_pinned(cref, true);
            }
            page = chunk_end;
        }
    }

    /// Time for the GPU to pull `bytes` over the link by remote access.
    pub(super) fn remote_time(&self, bytes: Bytes) -> Ns {
        transfer_ns(bytes, self.plat.link.remote_bw)
    }

    /// Transfer-mode shortcut.
    pub(super) fn eff(&self, mode: TransferMode) -> f64 {
        self.plat.link.efficiency(mode)
    }

    /// Like [`UmRuntime::eff`], but degraded by the chaos layer's
    /// link-episode schedule at simulated time `now` (the link-degrade
    /// and storm scenarios, `sim/inject.rs`). The `None` arm skips
    /// even the `* 1.0` multiply, so runs with injection disabled are
    /// byte-identical to the un-instrumented runtime by construction.
    pub(super) fn eff_at(&self, mode: TransferMode, now: Ns) -> f64 {
        let base = self.plat.link.efficiency(mode);
        match &self.inject {
            Some(inj) => base * inj.link_factor(now),
            None => base,
        }
    }

    /// Per-access chaos perturbations (ECC retirement, spurious fault
    /// noise). Returns the access's possibly delayed start time. Each
    /// episode is why-annotated: a `chaos.*` decision per link-degrade
    /// edge, retired chunk and noise burst (`docs/OBSERVABILITY.md`).
    fn chaos_on_access(&mut self, id: AllocId, now: Ns) -> Ns {
        let Some(inj) = &mut self.inject else { return now };
        let retire = inj.should_retire_chunk();
        let noise = inj.fault_noise();
        let factor = inj.link_factor(now);
        let rung = self.current_rung();
        let stream = self.access_stream;
        let degraded = factor < 1.0;
        if degraded && !self.chaos_link_degraded {
            self.trace.decision(Decision {
                at: now,
                stream,
                alloc: None,
                rung,
                reason: ReasonCode::ChaosLinkDegrade,
                bytes: 0,
                aux: (factor * 100.0) as u64,
            });
        }
        self.chaos_link_degraded = degraded;
        if retire {
            self.chaos_retire_chunk(now);
        }
        match noise {
            Some(pages) => {
                self.trace.decision(Decision {
                    at: now,
                    stream,
                    alloc: Some(id),
                    rung,
                    reason: ReasonCode::ChaosFaultNoise,
                    bytes: u64::from(pages) * PAGE_SIZE,
                    aux: u64::from(pages),
                });
                self.service_faults(id, pages, false, false, 1.0, now, "chaos-noise").0
            }
            None => now,
        }
    }

    /// ECC-style quarantine of one 2 MiB chunk (the ecc-retire and
    /// storm scenarios): evict to free a chunk's worth of space if
    /// necessary, then shrink usable capacity. Never panics a run —
    /// retirement is skipped once capacity would drop below half the
    /// device (the injector models isolated page retirements, not a
    /// dying board) and when nothing is evictable (everything
    /// `cudaMalloc`-locked). Undone by [`UmRuntime::reset_run_state`].
    fn chaos_retire_chunk(&mut self, now: Ns) {
        const CHUNK_BYTES: Bytes = PAGES_PER_CHUNK as Bytes * PAGE_SIZE;
        if self.dev.capacity() < self.plat.gpu.usable() / 2 + CHUNK_BYTES {
            return;
        }
        if self.dev.free() < CHUNK_BYTES && !self.dev.any_evictable() {
            return;
        }
        self.ensure_device_space(CHUNK_BYTES, now);
        self.dev.retire(CHUNK_BYTES);
        self.trace.decision(Decision {
            at: now,
            stream: self.access_stream,
            alloc: None,
            rung: self.current_rung(),
            reason: ReasonCode::ChaosEccRetire,
            bytes: CHUNK_BYTES,
            aux: 0,
        });
    }

    /// Record a transiently failed bulk-prefetch piece (the
    /// flaky-prefetch scenario) for the watchdog's bounded retry. The
    /// queue is a capped retry work-list, not a log: beyond the cap
    /// the pages simply wait for a demand fault.
    pub(super) fn note_failed_prefetch(&mut self, id: AllocId, piece: PageRange) {
        const CAP: usize = 64;
        if self.failed_prefetches.len() < CAP {
            self.failed_prefetches.push_back((id, piece));
        }
        self.metrics.chaos_failed_prefetch_bytes += piece.bytes();
    }

    /// Reset all run state (new repetition) keeping allocations' *sizes*
    /// but clearing page state, residency, clocks, metrics, trace.
    pub fn reset_run_state(&mut self) {
        for i in 0..self.space.len() {
            let id = AllocId(i as u32);
            let kind = self.space.get(id).kind;
            let n = self.space.get(id).n_pages();
            // Segment-native reset: one `set_range` collapses the whole
            // allocation to a single uniform segment — O(1) per alloc
            // per benchmark repetition instead of a per-page walk.
            let st = if kind == AllocKind::Managed {
                PageState::default()
            } else {
                PageState {
                    residency: if kind == AllocKind::Device {
                        Residency::Device
                    } else {
                        Residency::Host
                    },
                    flags: PageFlags(PageFlags::POPULATED),
                    ..Default::default()
                }
            };
            self.space.get_mut(id).pages.set_range(PageRange::new(0, n), st);
        }
        self.advise_hints_active = false;
        if let Some(eng) = &mut self.auto {
            eng.reset();
        }
        self.evict_hints.clear();
        self.evict_audit.clear();
        self.evict_deferred.clear();
        // Fresh injector: every repetition replays the same schedule
        // (the zero-variance invariant in `driver.rs` depends on it).
        self.inject = Injector::new(self.policy.inject);
        self.failed_prefetches.clear();
        self.chaos_link_degraded = false;
        self.counter_touches.clear();
        self.counter_threshold_hints.clear();
        self.coherent_avoided_remote = 0;
        self.dev.reset();
        self.dma_h2d.reset();
        self.dma_d2h.reset();
        self.fault_path.reset();
        self.metrics.reset();
        // Same mode and cap, empty buffers: a capped suite trace stays
        // capped across repetitions.
        self.trace = self.trace.fresh();
        // Re-pin cudaMalloc allocations.
        for i in 0..self.space.len() {
            let id = AllocId(i as u32);
            if self.space.get(id).kind == AllocKind::Device {
                let n_pages = self.space.get(id).n_pages();
                for chunk in 0..n_pages.div_ceil(PAGES_PER_CHUNK) {
                    let pages_in_chunk = (n_pages - chunk * PAGES_PER_CHUNK).min(PAGES_PER_CHUNK);
                    let cref = ChunkRef { alloc: id, chunk };
                    self.dev.add_resident(cref, pages_in_chunk as u64 * PAGE_SIZE, Ns::ZERO);
                    self.dev.set_locked(cref, true);
                }
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{intel_pascal, p9_volta};
    use crate::util::units::{GIB, MIB};

    fn rt() -> UmRuntime {
        UmRuntime::new(&intel_pascal())
    }

    #[test]
    fn managed_alloc_starts_unmapped() {
        let mut r = rt();
        let a = r.malloc_managed("x", 64 * MIB);
        let alloc = r.space.get(a);
        assert_eq!(alloc.pages.count(alloc.full(), |p| p.residency == Residency::Unmapped), alloc.n_pages());
        assert_eq!(r.dev.used(), 0);
    }

    #[test]
    fn device_alloc_is_resident_and_pinned() {
        let mut r = rt();
        let a = r.malloc_device("d", 8 * MIB);
        assert_eq!(r.dev.used(), 8 * MIB);
        // pinned: non-forced LRU pop can't evict it
        assert!(r.dev.pop_lru(false).is_none());
        let alloc = r.space.get(a);
        assert_eq!(alloc.pages.count(alloc.full(), |p| p.residency == Residency::Device), alloc.n_pages());
    }

    #[test]
    fn explicit_memcpy_not_fault_path() {
        let mut r = rt();
        let d = r.malloc_device("d", 8 * MIB);
        let end = r.memcpy_h2d(d, 8 * MIB, Ns::ZERO);
        assert!(end > Ns::ZERO);
        assert_eq!(r.metrics.gpu_fault_groups, 0);
        assert_eq!(r.metrics.h2d_bytes, 8 * MIB);
    }

    #[test]
    fn gpu_access_to_device_alloc_is_free() {
        let mut r = rt();
        let d = r.malloc_device("d", 8 * MIB);
        let full = r.space.get(d).full();
        let out = r.gpu_access(d, full, false, Ns(5));
        assert_eq!(out.done, Ns(5));
        assert_eq!(out.fault_stall, Ns::ZERO);
    }

    #[test]
    fn first_gpu_touch_populates_without_transfer() {
        let mut r = rt();
        let a = r.malloc_managed("x", 16 * MIB);
        let full = r.space.get(a).full();
        let out = r.gpu_access(a, full, true, Ns::ZERO);
        assert!(out.done > Ns::ZERO, "population costs fault handling");
        assert_eq!(out.h2d_bytes, 0, "no data moves for first-touch populate");
        assert_eq!(r.dev.used(), 16 * MIB);
        let alloc = r.space.get(a);
        assert_eq!(alloc.pages.count(alloc.full(), |p| p.residency == Residency::Device), alloc.n_pages());
    }

    #[test]
    fn second_access_is_free() {
        let mut r = rt();
        let a = r.malloc_managed("x", 16 * MIB);
        let full = r.space.get(a).full();
        let first = r.gpu_access(a, full, false, Ns::ZERO);
        let second = r.gpu_access(a, full, false, first.done);
        assert_eq!(second.done, first.done, "resident access has no cost");
        assert_eq!(second.fault_stall, Ns::ZERO);
    }

    #[test]
    fn reset_run_state_clears_everything() {
        let mut r = rt();
        let a = r.malloc_managed("x", 16 * MIB);
        let d = r.malloc_device("d", 4 * MIB);
        let full = r.space.get(a).full();
        r.gpu_access(a, full, true, Ns::ZERO);
        r.reset_run_state();
        assert_eq!(r.metrics, UmMetrics::default());
        assert_eq!(r.dev.used(), 4 * MIB, "device alloc re-pinned, managed cleared");
        let alloc = r.space.get(a);
        assert_eq!(alloc.pages.count(alloc.full(), |p| p.residency == Residency::Unmapped), alloc.n_pages());
        let _ = d;
    }

    #[test]
    fn oversubscribed_footprint_allocatable() {
        // Allocating more managed memory than the device holds is legal;
        // faults + eviction deal with it at access time.
        let mut r = UmRuntime::new(&p9_volta());
        let a = r.malloc_managed("big", 24 * GIB);
        assert!(r.space.get(a).size > r.dev.capacity());
    }
}
