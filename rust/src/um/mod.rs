//! The Unified Memory runtime simulator — the substrate the paper
//! evaluates.
//!
//! [`runtime::UmRuntime`] is the facade; its mechanisms are split across
//! `impl` blocks by concern:
//!
//! * [`fault`] — GPU fault groups: batching, service cost, replay.
//! * [`migrate`] — on-demand migration, density-prefetch escalation.
//! * [`advise`] — `cudaMemAdvise{SetReadMostly, SetPreferredLocation,
//!   SetAccessedBy}` semantics and their interplay with prefetch.
//! * [`prefetch`] — `cudaMemPrefetchAsync` bulk transfers.
//! * [`evict`] — LRU eviction under oversubscription, writeback-vs-drop,
//!   the pre-eviction ablation, the `um::auto` eviction-hint seam
//!   (`--evictor learned`, `docs/EVICTION.md`) and the eviction-quality
//!   audit (live-evicted vs. dead-hit bytes).
//! * [`host`] — host-side access paths (first-touch population, CPU
//!   faults, ATS remote access).
//!
//! The state model lives in [`crate::mem`]; timing comes from
//! [`crate::sim`] resource timelines; every data movement is recorded in
//! a [`crate::trace::Trace`].
//!
//! [`auto`] sits on top of all of the above: an optional online policy
//! engine (the `UM Auto` variant) that observes the fault stream and
//! chooses prefetch/advise/eviction actions at runtime.

pub mod policy;
pub mod metrics;
pub mod runtime;
pub mod fault;
pub mod migrate;
pub mod advise;
pub mod prefetch;
pub mod evict;
pub mod host;
pub mod auto;

pub use auto::{
    AutoConfig, AutoEngine, DeadRange, EvictionForecast, LearnedPredictor, Prediction,
    PredictorKind, Watchdog, WatchdogConfig, WatchdogMode,
};
pub use metrics::{StreamMetrics, UmMetrics};
pub use policy::{Advise, EvictorKind, Loc, UmPolicy};
pub use runtime::{AccessOutcome, UmRuntime};
