//! Counters the UM runtime accumulates per simulated run. Figures 4/7
//! use the trace's time totals; these counters power assertions, the
//! `umbra trace` summary and the ablation benches.

use crate::util::units::{Bytes, Ns};

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct UmMetrics {
    /// GPU fault groups serviced.
    pub gpu_fault_groups: u64,
    /// Pages covered by those groups (after dedup).
    pub gpu_faulted_pages: u64,
    /// Pages populated on device by first touch (no data movement).
    pub populated_dev_pages: u64,
    /// Pages populated on host by first touch.
    pub populated_host_pages: u64,
    /// Pages migrated host→device on demand (fault-driven).
    pub migrated_pages_h2d: u64,
    /// Pages migrated device→host on demand (CPU faults).
    pub migrated_pages_d2h: u64,
    /// Pages duplicated by ReadMostly (host copy retained).
    pub duplicated_pages: u64,
    /// Pages moved by prefetch, either direction.
    pub prefetched_pages_h2d: u64,
    pub prefetched_pages_d2h: u64,
    /// Eviction chunks selected.
    pub evicted_chunks: u64,
    /// Eviction bytes written back (had to be transferred).
    pub writeback_bytes: Bytes,
    /// Eviction bytes dropped for free (valid host copy existed).
    pub dropped_bytes: Bytes,
    /// Bytes served by GPU remote access to host memory (zero-copy).
    pub remote_bytes_gpu_to_host: Bytes,
    /// Bytes served by CPU remote access to device memory (ATS).
    pub remote_bytes_cpu_to_dev: Bytes,
    /// ReadMostly duplicate invalidations (pages).
    pub invalidated_pages: u64,
    /// CPU page faults serviced.
    pub cpu_faults: u64,
    /// `cudaMemAdvise` calls.
    pub advise_calls: u64,
    /// `cudaMemPrefetchAsync` calls.
    pub prefetch_calls: u64,
    /// Aggregate fault-stall occupancy (driver time GPU accesses waited).
    pub fault_stall: Ns,
    /// Aggregate H2D / D2H transfer occupancy.
    pub h2d_time: Ns,
    pub d2h_time: Ns,
    pub h2d_bytes: Bytes,
    pub d2h_bytes: Bytes,
}

impl UmMetrics {
    pub fn reset(&mut self) {
        *self = UmMetrics::default();
    }

    /// Total bytes that crossed the link in either direction.
    pub fn link_bytes(&self) -> Bytes {
        self.h2d_bytes + self.d2h_bytes
            + self.remote_bytes_gpu_to_host
            + self.remote_bytes_cpu_to_dev
    }

    /// The paper's "thrashing" indicator: eviction traffic comparable to
    /// (or exceeding) the forward migration traffic.
    pub fn thrash_ratio(&self) -> f64 {
        if self.h2d_bytes == 0 {
            0.0
        } else {
            self.d2h_bytes as f64 / self.h2d_bytes as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_zero() {
        let m = UmMetrics::default();
        assert_eq!(m.gpu_fault_groups, 0);
        assert_eq!(m.link_bytes(), 0);
        assert_eq!(m.thrash_ratio(), 0.0);
    }

    #[test]
    fn link_bytes_sums_all_paths() {
        let m = UmMetrics {
            h2d_bytes: 100,
            d2h_bytes: 50,
            remote_bytes_gpu_to_host: 25,
            remote_bytes_cpu_to_dev: 10,
            ..Default::default()
        };
        assert_eq!(m.link_bytes(), 185);
    }

    #[test]
    fn thrash_ratio_balanced() {
        let m = UmMetrics { h2d_bytes: 100, d2h_bytes: 100, ..Default::default() };
        assert!((m.thrash_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let mut m = UmMetrics { gpu_fault_groups: 5, ..Default::default() };
        m.reset();
        assert_eq!(m, UmMetrics::default());
    }
}
