//! Counters the UM runtime accumulates per simulated run. Figures 4/7
//! use the trace's time totals; these counters power assertions, the
//! `umbra trace` summary and the ablation benches.

use crate::gpu::stream::StreamId;
use crate::util::stats::LogHist;
use crate::util::units::{Bytes, Ns};

/// Streams with their own [`StreamMetrics`] slot; accesses on streams
/// beyond this collapse into the last slot (the `--streams` knob is a
/// small-N concurrency study, not a stream stress test).
pub const MAX_STREAM_METRICS: usize = 8;

/// Per-stream slice of the runtime counters: which stream drove the
/// access, which fault groups it paid for, and what the `um::auto`
/// engine decided on its behalf (state is keyed by
/// `(StreamId, AllocId)`, so decision counters are per-stream too).
#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct StreamMetrics {
    /// GPU accesses that originated on this stream.
    pub gpu_accesses: u64,
    /// Host accesses attributed to this stream (host ops run on the
    /// default stream's timeline).
    pub host_accesses: u64,
    /// Fault groups serviced on behalf of this stream's accesses.
    pub fault_groups: u64,
    /// `um::auto` actuations committed for this stream's accesses.
    pub auto_decisions: u64,
    /// Predictive-prefetch ranges issued from this stream's histories.
    pub auto_predictions: u64,
    /// Stable per-(stream, allocation) pattern flips.
    pub auto_pattern_flips: u64,
    /// Bytes moved by engine prefetches for this stream (escalation +
    /// prediction).
    pub auto_prefetched_bytes: Bytes,
}

impl StreamMetrics {
    /// Whether any counter is non-zero (drives report row inclusion).
    pub fn any(&self) -> bool {
        *self != StreamMetrics::default()
    }
}

/// NaN-safe percentage rendering for the decision-quality ratios: a
/// cell where nothing resolved must read "n/a", never a literal `NaN`
/// (and never a flattering 100%).
pub fn fmt_pct(x: f64) -> String {
    if x.is_finite() {
        format!("{:.0}%", x * 100.0)
    } else {
        "n/a".into()
    }
}

/// NaN-safe fraction rendering for CSV cells ("-" when unresolved, so
/// downstream tooling never parses a literal `NaN`).
pub fn fmt_frac(x: f64) -> String {
    if x.is_finite() {
        format!("{x:.4}")
    } else {
        "-".into()
    }
}

#[derive(Clone, Copy, Debug, Default, PartialEq)]
pub struct UmMetrics {
    /// GPU fault groups serviced.
    pub gpu_fault_groups: u64,
    /// Pages covered by those groups (after dedup).
    pub gpu_faulted_pages: u64,
    /// Pages populated on device by first touch (no data movement).
    pub populated_dev_pages: u64,
    /// Pages populated on host by first touch.
    pub populated_host_pages: u64,
    /// Pages migrated host→device on demand (fault-driven).
    pub migrated_pages_h2d: u64,
    /// Pages migrated device→host on demand (CPU faults).
    pub migrated_pages_d2h: u64,
    /// Pages duplicated by ReadMostly (host copy retained).
    pub duplicated_pages: u64,
    /// Pages moved by prefetch, either direction.
    pub prefetched_pages_h2d: u64,
    pub prefetched_pages_d2h: u64,
    /// Eviction chunks selected.
    pub evicted_chunks: u64,
    /// Eviction bytes written back (had to be transferred).
    pub writeback_bytes: Bytes,
    /// Eviction bytes dropped for free (valid host copy existed).
    pub dropped_bytes: Bytes,
    /// Bytes served by GPU remote access to host memory (zero-copy).
    pub remote_bytes_gpu_to_host: Bytes,
    /// Bytes served by CPU remote access to device memory (ATS).
    pub remote_bytes_cpu_to_dev: Bytes,
    /// ReadMostly duplicate invalidations (pages).
    pub invalidated_pages: u64,
    /// CPU page faults serviced.
    pub cpu_faults: u64,
    /// `cudaMemAdvise` calls.
    pub advise_calls: u64,
    /// `cudaMemPrefetchAsync` calls.
    pub prefetch_calls: u64,
    /// Aggregate fault-stall occupancy (driver time GPU accesses waited).
    pub fault_stall: Ns,
    /// Aggregate H2D / D2H transfer occupancy.
    pub h2d_time: Ns,
    pub d2h_time: Ns,
    pub h2d_bytes: Bytes,
    pub d2h_bytes: Bytes,

    // --- um::auto policy-engine counters (zero unless `UM Auto`) ---
    /// Actuations committed (escalations, predictions, advises, hints).
    pub auto_decisions: u64,
    /// Stable pattern changes that survived hysteresis.
    pub auto_pattern_flips: u64,
    /// Bytes moved by engine-issued prefetches (escalation + prediction).
    pub auto_prefetched_bytes: Bytes,
    /// Predictively prefetched bytes later consumed by an access (hits).
    pub auto_prefetch_hit_bytes: Bytes,
    /// Predictively prefetched bytes that aged out unused.
    pub auto_mispredicted_prefetch_bytes: Bytes,
    /// ReadMostly set/unset actuations.
    pub auto_advises: u64,
    /// Bytes dropped early by streamed-past eviction hints.
    pub auto_early_dropped_bytes: Bytes,
    /// Learned-predictor consultations (post-access steps in learned
    /// mode; the denominator of prediction *coverage*).
    pub auto_predict_queries: u64,
    /// Consultations that yielded at least one above-threshold learned
    /// prediction (coverage = confident / queries).
    pub auto_predict_confident: u64,
    /// Ranked predictions issued from the learned delta-history tables.
    pub auto_learned_predictions: u64,
    /// Predictions issued by the heuristic classifier rule while the
    /// learned tables were below the confidence gate.
    pub auto_fallback_predictions: u64,
    /// Eviction-quality: evicted (or early-dropped) bytes the GPU
    /// *demanded* again later in the same run — the eviction was
    /// wrong. Charged at the demand point (re-migration, remote-mapped
    /// re-read, or a demand touch of data a prefetch brought back), so
    /// speculative prefetch-back alone never counts. Tracked in every
    /// mode and for every variant (pure bookkeeping on the eviction
    /// audit); the `fig_evict` study compares it across policies.
    pub evict_live_evicted_bytes: Bytes,
    /// Eviction-quality: evicted bytes the GPU never demanded again by
    /// the end of the run — the eviction was right. Flushed from the
    /// audit by `UmRuntime::finish_eviction_audit` (called once per
    /// run).
    pub evict_dead_hit_bytes: Bytes,
    /// Bytes of bulk-prefetch pieces that failed transiently under
    /// fault injection (the flaky-prefetch scenario, `sim/inject.rs`).
    /// Always zero with injection off. Counted at failure time — a
    /// piece the watchdog later retries successfully still counts (it
    /// *did* fail once).
    pub chaos_failed_prefetch_bytes: Bytes,

    // --- um::auto watchdog counters (docs/ROBUSTNESS.md) ---
    /// Watchdog trips: degradation-ladder steps taken down
    /// (Full → Heuristic → NoAdvise → Inert).
    pub wd_trips: u64,
    /// Watchdog recoveries: ladder steps climbed back up after clean
    /// re-arm probes.
    pub wd_recoveries: u64,
    /// Failed-prefetch pieces re-issued by the watchdog's bounded
    /// retry.
    pub wd_retries: u64,
    /// Observation windows spent in any degraded mode (dwell time,
    /// measured in windows).
    pub wd_degraded_windows: u64,

    // --- coherent-platform counters (docs/PLATFORMS.md) ---
    // Always zero on the fault-driven platforms: only the coherent
    // servicing path in `um/migrate.rs` bumps them, and that path is
    // unreachable unless `UmPolicy::coherent` (pinned by
    // `rust/tests/platform_oracle.rs`).
    /// Bytes the GPU pulled from host memory over the coherent fabric
    /// at line granularity (the no-fault servicing mode). A subset of
    /// `remote_bytes_gpu_to_host`, split out so the coherent column is
    /// distinguishable from legacy zero-copy/ATS traffic in the CSV.
    pub remote_access_bytes: Bytes,
    /// Background migrations triggered by a hardware access-counter
    /// group crossing its threshold (one per migrated run∩group
    /// extent).
    pub counter_migrations: u64,
    /// Access-counter groups that crossed `counter_threshold` (each
    /// group counted once per run — the edge, not the dwell).
    pub counter_threshold_crossings: u64,

    // --- latency/size distributions (docs/OBSERVABILITY.md) ---
    // Fed unconditionally on the hot path (fixed-size, O(1) record),
    // never through the trace gate, so enabling/capping/disabling
    // tracing cannot change them — the observer-effect oracle compares
    // whole `UmMetrics` values across trace modes.
    /// Fault-group service latency distribution (ns per group).
    pub fault_latency: LogHist,
    /// Transfer-size distribution (bytes per DMA/memcpy occupancy).
    pub transfer_size: LogHist,
    /// Predictive-prefetch issue-to-consume lag distribution (ns from
    /// the issuing decision to the access that consumed it).
    pub prefetch_lag: LogHist,
    /// Per-stream counter slices (slot = stream index, clamped to
    /// [`MAX_STREAM_METRICS`]); all-zero except for streams that
    /// actually drove accesses.
    pub per_stream: [StreamMetrics; MAX_STREAM_METRICS],
}

impl UmMetrics {
    pub fn reset(&mut self) {
        *self = UmMetrics::default();
    }

    /// The mutable per-stream slot for `s` (streams past the tracked
    /// range share the last slot).
    pub fn stream_mut(&mut self, s: StreamId) -> &mut StreamMetrics {
        &mut self.per_stream[s.index().min(MAX_STREAM_METRICS - 1)]
    }

    /// Streams that recorded any activity, as `(stream index, slice)`
    /// pairs in stream order (report/JSON rows).
    pub fn active_streams(&self) -> impl Iterator<Item = (usize, &StreamMetrics)> {
        self.per_stream.iter().enumerate().filter(|(_, m)| m.any())
    }

    /// Total bytes that crossed the link in either direction.
    pub fn link_bytes(&self) -> Bytes {
        self.h2d_bytes + self.d2h_bytes
            + self.remote_bytes_gpu_to_host
            + self.remote_bytes_cpu_to_dev
    }

    /// The paper's "thrashing" indicator: eviction traffic comparable to
    /// (or exceeding) the forward migration traffic.
    pub fn thrash_ratio(&self) -> f64 {
        if self.h2d_bytes == 0 {
            0.0
        } else {
            self.d2h_bytes as f64 / self.h2d_bytes as f64
        }
    }

    /// Share of engine-prefetched bytes that aged out unused
    /// (`auto_mispredicted_bytes / auto_prefetched_bytes`) — the
    /// decision-quality figure the suite JSON tracks across PRs.
    /// 0.0 when nothing was prefetched.
    pub fn misprediction_ratio(&self) -> f64 {
        if self.auto_prefetched_bytes == 0 {
            0.0
        } else {
            self.auto_mispredicted_prefetch_bytes as f64 / self.auto_prefetched_bytes as f64
        }
    }

    /// Of the predictively prefetched bytes whose fate is known, the
    /// fraction an access actually consumed
    /// (`hit / (hit + mispredicted)`). NaN when nothing has resolved —
    /// a cell where the predictor never predicted must render as "n/a"
    /// (JSON `null`), not as a flattering 100%.
    pub fn prediction_accuracy(&self) -> f64 {
        let resolved = self.auto_prefetch_hit_bytes + self.auto_mispredicted_prefetch_bytes;
        if resolved == 0 {
            f64::NAN
        } else {
            self.auto_prefetch_hit_bytes as f64 / resolved as f64
        }
    }

    /// Fraction of learned-predictor consultations that produced an
    /// above-threshold prediction (learned mode only; 0.0 before any
    /// consultation).
    pub fn prediction_coverage(&self) -> f64 {
        if self.auto_predict_queries == 0 {
            0.0
        } else {
            self.auto_predict_confident as f64 / self.auto_predict_queries as f64
        }
    }

    /// CSV column names for the auto-policy counters (kept in lockstep
    /// with [`UmMetrics::auto_csv_row`]; suite/report CSVs append these
    /// so the bench trajectory tracks decision quality across PRs).
    /// (`'static` is required here: associated constants may not elide
    /// lifetimes — rustc's `elided_lifetimes_in_associated_constant`.)
    /// New columns append at the end — downstream tooling (and the
    /// positional assertions in this module's tests) index the earlier
    /// columns by position.
    pub const AUTO_CSV_HEADER: [&'static str; 29] = [
        "auto_decisions",
        "auto_pattern_flips",
        "auto_prefetched_bytes",
        "auto_prefetch_hit_bytes",
        "auto_mispredicted_bytes",
        "auto_advises",
        "auto_early_dropped_bytes",
        "auto_predict_queries",
        "auto_predict_confident",
        "auto_learned_predictions",
        "auto_fallback_predictions",
        "evict_live_evicted_bytes",
        "evict_dead_hit_bytes",
        "wd_trips",
        "wd_recoveries",
        "wd_retries",
        "wd_degraded_windows",
        "fault_ns_p50",
        "fault_ns_p90",
        "fault_ns_p99",
        "xfer_bytes_p50",
        "xfer_bytes_p90",
        "xfer_bytes_p99",
        "lag_ns_p50",
        "lag_ns_p90",
        "lag_ns_p99",
        "remote_access_bytes",
        "counter_migrations",
        "counter_threshold_crossings",
    ];

    /// The auto-policy counters as CSV fields (order matches
    /// [`UmMetrics::AUTO_CSV_HEADER`]).
    pub fn auto_csv_row(&self) -> Vec<String> {
        vec![
            self.auto_decisions.to_string(),
            self.auto_pattern_flips.to_string(),
            self.auto_prefetched_bytes.to_string(),
            self.auto_prefetch_hit_bytes.to_string(),
            self.auto_mispredicted_prefetch_bytes.to_string(),
            self.auto_advises.to_string(),
            self.auto_early_dropped_bytes.to_string(),
            self.auto_predict_queries.to_string(),
            self.auto_predict_confident.to_string(),
            self.auto_learned_predictions.to_string(),
            self.auto_fallback_predictions.to_string(),
            self.evict_live_evicted_bytes.to_string(),
            self.evict_dead_hit_bytes.to_string(),
            self.wd_trips.to_string(),
            self.wd_recoveries.to_string(),
            self.wd_retries.to_string(),
            self.wd_degraded_windows.to_string(),
            self.fault_latency.p50().to_string(),
            self.fault_latency.p90().to_string(),
            self.fault_latency.p99().to_string(),
            self.transfer_size.p50().to_string(),
            self.transfer_size.p90().to_string(),
            self.transfer_size.p99().to_string(),
            self.prefetch_lag.p50().to_string(),
            self.prefetch_lag.p90().to_string(),
            self.prefetch_lag.p99().to_string(),
            self.remote_access_bytes.to_string(),
            self.counter_migrations.to_string(),
            self.counter_threshold_crossings.to_string(),
        ]
    }

    /// Of the evicted bytes whose fate is known, the fraction the
    /// workload never demanded back (`dead / (dead + live)`) — higher
    /// means victim selection picked genuinely dead data. NaN when
    /// nothing was evicted (render via [`fmt_pct`]/[`fmt_frac`], never
    /// as a flattering 100%).
    pub fn eviction_dead_ratio(&self) -> f64 {
        let resolved = self.evict_dead_hit_bytes + self.evict_live_evicted_bytes;
        if resolved == 0 {
            f64::NAN
        } else {
            self.evict_dead_hit_bytes as f64 / resolved as f64
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_zero() {
        let m = UmMetrics::default();
        assert_eq!(m.gpu_fault_groups, 0);
        assert_eq!(m.link_bytes(), 0);
        assert_eq!(m.thrash_ratio(), 0.0);
    }

    #[test]
    fn link_bytes_sums_all_paths() {
        let m = UmMetrics {
            h2d_bytes: 100,
            d2h_bytes: 50,
            remote_bytes_gpu_to_host: 25,
            remote_bytes_cpu_to_dev: 10,
            ..Default::default()
        };
        assert_eq!(m.link_bytes(), 185);
    }

    #[test]
    fn thrash_ratio_balanced() {
        let m = UmMetrics { h2d_bytes: 100, d2h_bytes: 100, ..Default::default() };
        assert!((m.thrash_ratio() - 1.0).abs() < 1e-12);
    }

    #[test]
    fn reset_clears() {
        let mut m = UmMetrics { gpu_fault_groups: 5, auto_decisions: 3, ..Default::default() };
        m.reset();
        assert_eq!(m, UmMetrics::default());
    }

    #[test]
    fn auto_csv_row_matches_header_width() {
        let m = UmMetrics {
            auto_decisions: 7,
            auto_prefetched_bytes: 4096,
            auto_learned_predictions: 3,
            ..Default::default()
        };
        let row = m.auto_csv_row();
        assert_eq!(row.len(), UmMetrics::AUTO_CSV_HEADER.len());
        assert_eq!(row[0], "7");
        assert_eq!(row[2], "4096");
        assert_eq!(row[9], "3");
    }

    #[test]
    fn percentile_columns_append_at_the_end() {
        let mut m = UmMetrics::default();
        for _ in 0..10 {
            m.fault_latency.record(1500);
            m.transfer_size.record(2 << 20);
            m.prefetch_lag.record(100_000);
        }
        let row = m.auto_csv_row();
        let idx = |name: &str| {
            UmMetrics::AUTO_CSV_HEADER
                .iter()
                .position(|h| *h == name)
                .unwrap_or_else(|| panic!("{name} missing from AUTO_CSV_HEADER"))
        };
        assert_eq!(row[idx("fault_ns_p50")], (1024 + 512).to_string());
        assert_eq!(row[idx("xfer_bytes_p99")], ((2 << 20) + (1 << 20)).to_string());
        assert_eq!(row[idx("lag_ns_p90")], (65536 + 32768).to_string());
        // Positional compatibility: the original 17 columns keep their
        // indices, so pre-existing consumers never re-map.
        assert_eq!(UmMetrics::AUTO_CSV_HEADER[16], "wd_degraded_windows");
        assert_eq!(idx("fault_ns_p50"), 17);
    }

    #[test]
    fn watchdog_counters_ride_in_the_csv() {
        let m = UmMetrics {
            wd_trips: 2,
            wd_recoveries: 1,
            wd_retries: 5,
            wd_degraded_windows: 9,
            ..Default::default()
        };
        let row = m.auto_csv_row();
        let idx = |name: &str| {
            UmMetrics::AUTO_CSV_HEADER
                .iter()
                .position(|h| *h == name)
                .unwrap_or_else(|| panic!("{name} missing from AUTO_CSV_HEADER"))
        };
        assert_eq!(row[idx("wd_trips")], "2");
        assert_eq!(row[idx("wd_recoveries")], "1");
        assert_eq!(row[idx("wd_retries")], "5");
        assert_eq!(row[idx("wd_degraded_windows")], "9");
    }

    #[test]
    fn coherent_columns_append_at_the_end() {
        let m = UmMetrics {
            remote_access_bytes: 123_456,
            counter_migrations: 7,
            counter_threshold_crossings: 5,
            ..Default::default()
        };
        let row = m.auto_csv_row();
        let idx = |name: &str| {
            UmMetrics::AUTO_CSV_HEADER
                .iter()
                .position(|h| *h == name)
                .unwrap_or_else(|| panic!("{name} missing from AUTO_CSV_HEADER"))
        };
        assert_eq!(row[idx("remote_access_bytes")], "123456");
        assert_eq!(row[idx("counter_migrations")], "7");
        assert_eq!(row[idx("counter_threshold_crossings")], "5");
        // Append-only contract: the coherent columns sit strictly after
        // every pre-existing column.
        assert_eq!(idx("remote_access_bytes"), 26);
        assert_eq!(UmMetrics::AUTO_CSV_HEADER.len(), 29);
    }

    #[test]
    fn per_stream_slots_clamp_and_filter() {
        let mut m = UmMetrics::default();
        m.stream_mut(StreamId(0)).gpu_accesses += 1;
        m.stream_mut(StreamId(2)).auto_decisions += 3;
        // Streams beyond the tracked range collapse into the last slot.
        m.stream_mut(StreamId(40)).gpu_accesses += 1;
        m.stream_mut(StreamId(99)).gpu_accesses += 1;
        assert_eq!(m.per_stream[MAX_STREAM_METRICS - 1].gpu_accesses, 2);
        let active: Vec<usize> = m.active_streams().map(|(i, _)| i).collect();
        assert_eq!(active, vec![0, 2, MAX_STREAM_METRICS - 1]);
        m.reset();
        assert!(m.active_streams().next().is_none());
    }

    #[test]
    fn eviction_dead_ratio_nan_until_resolved() {
        let m = UmMetrics::default();
        assert!(m.eviction_dead_ratio().is_nan(), "nothing evicted: n/a, not 100%");
        let m = UmMetrics {
            evict_dead_hit_bytes: 300,
            evict_live_evicted_bytes: 100,
            ..Default::default()
        };
        assert!((m.eviction_dead_ratio() - 0.75).abs() < 1e-12);
        let row = m.auto_csv_row();
        let idx = |name: &str| {
            UmMetrics::AUTO_CSV_HEADER.iter().position(|h| *h == name).unwrap()
        };
        assert_eq!(row[idx("evict_live_evicted_bytes")], "100", "live-evicted rides in the CSV");
        assert_eq!(row[idx("evict_dead_hit_bytes")], "300");
    }

    #[test]
    fn nan_safe_formatting_for_zero_resolved_cells() {
        // Regression: a run where no prediction ever resolved has NaN
        // accuracy; reports/CSVs must render "n/a"/"-", not "NaN".
        let m = UmMetrics::default();
        assert_eq!(fmt_pct(m.prediction_accuracy()), "n/a");
        assert_eq!(fmt_frac(m.prediction_accuracy()), "-");
        assert_eq!(fmt_pct(0.25), "25%");
        assert_eq!(fmt_frac(0.25), "0.2500");
        assert_eq!(fmt_pct(f64::INFINITY), "n/a");
    }

    #[test]
    fn decision_quality_ratios() {
        let m = UmMetrics::default();
        assert_eq!(m.misprediction_ratio(), 0.0);
        assert!(m.prediction_accuracy().is_nan(), "nothing resolved yet: n/a, not 100%");
        assert_eq!(m.prediction_coverage(), 0.0);
        let m = UmMetrics {
            auto_prefetched_bytes: 1000,
            auto_prefetch_hit_bytes: 600,
            auto_mispredicted_prefetch_bytes: 200,
            auto_predict_queries: 10,
            auto_predict_confident: 4,
            ..Default::default()
        };
        assert!((m.misprediction_ratio() - 0.2).abs() < 1e-12);
        assert!((m.prediction_accuracy() - 0.75).abs() < 1e-12);
        assert!((m.prediction_coverage() - 0.4).abs() < 1e-12);
    }
}
