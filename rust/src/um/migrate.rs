//! On-demand H2D migration, ATS remote mapping under pressure,
//! ReadMostly duplicate handling (paper §II-A/§II-B), and the coherent
//! platform's access-counter servicing path (`docs/PLATFORMS.md`).

use crate::mem::{AllocId, PageRange, Residency, TransferMode, PAGE_SIZE};
use crate::mem::page::PageFlags;
use crate::trace::TraceKind;
use crate::util::units::{Bytes, Ns};

use super::runtime::{AccessOutcome, Class, UmRuntime};

impl UmRuntime {
    /// GPU touched host-resident pages: migrate them on demand — or, on
    /// coherent platforms under memory pressure, map them remotely
    /// instead of migrating (the NVLink/ATS driver avoids eviction
    /// storms this way; PCIe platforms cannot, see DESIGN.md §1).
    /// Advised ranges (`ReadMostly` / `PreferredLocation(Gpu)`) force
    /// local placement — the documented cause of the paper's P9
    /// oversubscription pathology.
    pub(super) fn migrate_or_map_h2d(
        &mut self,
        id: AllocId,
        run: PageRange,
        class: Class,
        write: bool,
        now: Ns,
    ) -> AccessOutcome {
        let forces_local = class.read_mostly || class.pref_gpu;
        let mut migrate_run = run;
        let mut remote_run = PageRange::new(run.end, run.end);

        // Placement hints override the heuristic remote-overflow path
        // process-wide (DESIGN.md §1): with hints active the driver
        // strictly migrates + evicts.
        let heuristics_enabled =
            self.policy.remote_map_under_pressure && !self.advise_hints_active;
        if heuristics_enabled && !forces_local {
            // Migrate what fits without evicting; remote-map the rest.
            let free_pages = (self.dev.free() / PAGE_SIZE) as u32;
            if free_pages < run.len() {
                migrate_run = PageRange::new(run.start, run.start + free_pages);
                remote_run = PageRange::new(run.start + free_pages, run.end);
            }
        }

        let mut out = AccessOutcome { done: now, ..Default::default() };
        if !migrate_run.is_empty() {
            out.merge(self.migrate_h2d(id, migrate_run, class, write, now));
        }
        if !remote_run.is_empty() {
            out.merge(self.remote_access_host(id, remote_run, now));
        }
        out
    }

    /// Fault-driven migration of one homogeneous host-resident run.
    fn migrate_h2d(
        &mut self,
        id: AllocId,
        run: PageRange,
        class: Class,
        write: bool,
        now: Ns,
    ) -> AccessOutcome {
        // PreferredLocation(Gpu) buys the full 2 MiB fault escalation;
        // any advise (incl. ReadMostly) buys the cheaper fault service.
        let placed = class.pref_gpu;
        let advised = class.pref_gpu || class.read_mostly;

        // Fault groups (driver) then the migration DMA per group; the
        // DMA of group i overlaps the fault service of group i+1.
        // Space is reserved *per group*: runs larger than the remaining
        // (or even total) device capacity progressively evict — the
        // self-eviction cyclic-thrash behaviour of §IV-B.
        //
        // With `density_escalation` the granule ramps as streaming
        // density accumulates (the driver's tree prefetcher, [3]):
        // base, base, 2*base, 2*base, 4*base ... capped at the 2 MiB
        // advised granule.
        let base_group = self.policy.group_pages(placed);
        let cap_group = self.policy.advised_group_pages.max(base_group);
        let duplicate = class.read_mostly && !write;
        let mut ready = now;
        let mut done = now;
        let mut stall_total = Ns::ZERO;
        let mut page = run.start;
        let mut n_groups: u32 = 0;
        while page < run.end {
            // ETC-style thrash throttling ([10], ablation): once this
            // access's eviction churn exceeds the threshold, stop
            // honoring locality and map the remainder remotely
            // (coherent platforms only).
            if self.policy.etc_throttle
                && self.plat.cpu_can_access_gpu
                && self.access_evicted_bytes > self.policy.etc_threshold
            {
                break;
            }
            let group_pages = if self.policy.density_escalation && !placed {
                (base_group << (n_groups / 2).min(8)).min(cap_group)
            } else {
                base_group
            };
            n_groups += 1;
            let group = crate::mem::PageRange::new(page, (page + group_pages).min(run.end));
            page = group.end;
            let bytes = group.bytes();
            let t_space = self.ensure_device_space(bytes, ready);
            let service = self.policy.fault_service(group.len(), advised);
            let focc = self.fault_path.serve(t_space, service);
            self.metrics.fault_latency.record(service.0);
            self.trace.record_on(
                self.access_stream,
                TraceKind::GpuFaultGroup,
                focc.start,
                focc.end,
                bytes,
                Some(id),
                "migrate",
            );
            stall_total += service;
            // Per-group efficiency: the chaos layer's link episodes
            // (`eff_at`) can degrade mid-run.
            let eff_faulted = self.eff_at(TransferMode::Faulted, focc.end);
            let docc = self.dma_h2d.transfer(focc.end, bytes, eff_faulted);
            self.metrics.transfer_size.record(bytes);
            self.trace.record_on(
                self.access_stream,
                TraceKind::UmMemcpyHtoD,
                docc.start,
                docc.end,
                bytes,
                Some(id),
                "migrate",
            );
            self.metrics.h2d_time += docc.duration();
            // Page state + residency accounting as the group arrives.
            self.space.get_mut(id).pages.update(group, |p| {
                p.residency = if duplicate { Residency::Both } else { Residency::Device };
                p.flags.set(PageFlags::POPULATED, true);
                p.flags.set(PageFlags::DIRTY, write);
                p.flags.set(PageFlags::GPU_MAPPED, false);
            });
            self.add_device_residency(id, group, placed, docc.end);
            ready = focc.end; // driver proceeds to the next group
            done = done.max(docc.end);
        }
        // Duplicated faults from warp parallelism: extra driver-only
        // groups (no payload), still counted as stall.
        let dup_extra = ((n_groups as f64) * (self.policy.dup_fault_factor - 1.0)).ceil() as u64;
        for _ in 0..dup_extra {
            let service = self.policy.fault_service(1, advised);
            let focc = self.fault_path.serve(ready, service);
            self.metrics.fault_latency.record(service.0);
            self.trace.record_on(
                self.access_stream,
                TraceKind::GpuFaultGroup,
                focc.start,
                focc.end,
                0,
                Some(id),
                "dup-fault",
            );
            stall_total += service;
            ready = focc.end;
            done = done.max(focc.end);
        }
        // `page` is where migration stopped (== run.end unless the ETC
        // throttle broke out early).
        let migrated = crate::mem::PageRange::new(run.start, page);
        self.metrics.gpu_fault_groups += n_groups as u64 + dup_extra;
        self.metrics.gpu_faulted_pages += migrated.len() as u64;
        self.metrics.fault_stall += stall_total;
        self.metrics.migrated_pages_h2d += migrated.len() as u64;
        self.metrics.h2d_bytes += migrated.bytes();
        if duplicate {
            self.metrics.duplicated_pages += migrated.len() as u64;
        }

        let mut out = AccessOutcome {
            done,
            fault_stall: stall_total,
            transfer_wait: (done - now).saturating_sub(stall_total),
            h2d_bytes: migrated.bytes(),
            ..Default::default()
        };
        if page < run.end {
            // Throttled remainder: serve remotely.
            out.merge(self.remote_access_host(
                id,
                crate::mem::PageRange::new(page, run.end),
                done,
            ));
        }
        out
    }

    /// GPU accesses host memory in place (zero-copy over PCIe,
    /// ATS-coherent over NVLink). No migration; the accessor pays the
    /// remote bandwidth *every* access — callers fold `remote_bytes`
    /// into the kernel's execution-time model.
    pub(super) fn remote_access_host(&mut self, id: AllocId, run: PageRange, now: Ns) -> AccessOutcome {
        self.space.get_mut(id).pages.update(run, |p| {
            p.flags.set(PageFlags::GPU_MAPPED, true);
            p.flags.set(PageFlags::POPULATED, true);
            if p.residency == Residency::Unmapped {
                p.residency = Residency::Host;
            }
        });
        let bytes = run.bytes();
        let dur = self.remote_time(bytes);
        self.trace.record_on(
            self.access_stream,
            TraceKind::RemoteAccess,
            now,
            now + dur,
            bytes,
            Some(id),
            "gpu-remote",
        );
        self.metrics.remote_bytes_gpu_to_host += bytes;
        AccessOutcome { done: now, remote_bytes: bytes, ..Default::default() }
    }

    /// Coherent (Grace-Hopper-class) servicing of a host-resident run:
    /// the access itself is always serviced remotely at cache-line
    /// granularity over the C2C fabric — **no fault groups, no stall**
    /// — while the per-group hardware access counters accumulate
    /// touches. A group crossing `policy.counter_threshold` has its
    /// touched host pages migrated to the device *in the background*:
    /// the triggering access's `done` is not extended (it was already
    /// served remotely); only later accesses see the pages device-
    /// resident. `ReadMostly` and `PreferredLocation(Cpu)` pin the run
    /// remote (never migrate), as does `counter_threshold == 0`.
    pub(super) fn coherent_access_host(
        &mut self,
        id: AllocId,
        run: PageRange,
        class: Class,
        write: bool,
        now: Ns,
    ) -> AccessOutcome {
        debug_assert!(self.policy.coherent);
        let bytes = run.bytes();
        let dur = self.remote_time(bytes);
        self.trace.record_on(
            self.access_stream,
            TraceKind::RemoteAccess,
            now,
            now + dur,
            bytes,
            Some(id),
            "coherent",
        );
        self.metrics.remote_bytes_gpu_to_host += bytes;
        self.metrics.remote_access_bytes += bytes;
        let mut out = AccessOutcome { done: now, remote_bytes: bytes, ..Default::default() };

        // "Pin remote, never migrate": duplication is pointless on a
        // coherent fabric (every reader already sees the host copy at
        // near-local bandwidth) and `PreferredLocation(Cpu)` is an
        // explicit stay-put instruction. Threshold 0 disables the
        // counter path wholesale (an engine hint never resurrects it).
        if class.read_mostly || class.pref_host || self.policy.counter_threshold == 0 {
            return out;
        }
        // The auto engine may have re-tuned this allocation's threshold
        // from its observed pattern; fall back to the platform default.
        let threshold = self
            .counter_threshold_hints
            .get(&id)
            .copied()
            .unwrap_or(self.policy.counter_threshold);

        // Hardware access counters: one touch per overlapping group per
        // serviced run (the counters see coalesced traffic, not per-
        // line events). Crossing the threshold migrates the *touched*
        // extent of the hot group — run ∩ group — so migrated bytes
        // never exceed what the GPU actually accessed (pinned by
        // `rust/tests/prop_invariants.rs`).
        let gp = self.policy.counter_group_pages;
        let first_group = run.start / gp;
        let last_group = (run.end - 1) / gp;
        for g in first_group..=last_group {
            let touches = self.counter_touches.entry((id, g)).or_insert(0);
            *touches = touches.saturating_add(1);
            let hot = *touches >= threshold;
            if *touches == threshold {
                self.metrics.counter_threshold_crossings += 1;
            }
            if hot {
                let seg = PageRange::new(run.start.max(g * gp), run.end.min((g + 1) * gp));
                out.h2d_bytes += self.counter_migrate(id, seg, write, now);
            }
        }
        out
    }

    /// Background migration of a hot counter group's touched extent.
    /// Bulk-mode DMA (the driver batches counter-triggered moves like a
    /// prefetch, not like a fault drain); the caller's access is *not*
    /// gated on completion.
    fn counter_migrate(&mut self, id: AllocId, seg: PageRange, write: bool, now: Ns) -> Bytes {
        let bytes = seg.bytes();
        let t_space = self.ensure_device_space(bytes, now);
        let eff = self.eff_at(TransferMode::Bulk, t_space);
        let occ = self.dma_h2d.transfer(t_space, bytes, eff);
        self.metrics.transfer_size.record(bytes);
        self.trace.record_on(
            self.access_stream,
            TraceKind::UmMemcpyHtoD,
            occ.start,
            occ.end,
            bytes,
            Some(id),
            "counter-migrate",
        );
        self.metrics.h2d_time += occ.duration();
        self.space.get_mut(id).pages.update(seg, |p| {
            p.residency = Residency::Device;
            p.flags.set(PageFlags::POPULATED, true);
            p.flags.set(PageFlags::DIRTY, write);
            p.flags.set(PageFlags::GPU_MAPPED, false);
            p.flags.set(PageFlags::COUNTER_PLACED, true);
        });
        self.add_device_residency(id, seg, false, occ.end);
        self.metrics.migrated_pages_h2d += seg.len() as u64;
        self.metrics.h2d_bytes += bytes;
        self.metrics.counter_migrations += 1;
        bytes
    }

    /// GPU write to ReadMostly-duplicated pages: all duplicates are
    /// invalidated to preserve consistency (paper §II-B) — the host copy
    /// is dropped and the device copy becomes the only (dirty) one.
    pub(super) fn invalidate_duplicates(&mut self, id: AllocId, run: PageRange, now: Ns) -> AccessOutcome {
        let occ = self.fault_path.serve(now, self.policy.invalidation_cost);
        self.trace.record_on(
            self.access_stream,
            TraceKind::Invalidation,
            occ.start,
            occ.end,
            run.bytes(),
            Some(id),
            "collapse",
        );
        self.space.get_mut(id).pages.update(run, |p| {
            debug_assert_eq!(p.residency, Residency::Both);
            p.residency = Residency::Device;
            p.flags.set(PageFlags::DIRTY, true);
        });
        self.metrics.invalidated_pages += run.len() as u64;
        AccessOutcome {
            done: occ.end,
            fault_stall: occ.duration(),
            ..Default::default()
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::platform::{intel_pascal, p9_volta};
    use crate::util::units::{GIB, MIB};

    /// Host-initialize then GPU-read: the basic UM first-touch pattern.
    fn host_then_gpu(r: &mut UmRuntime, size: u64, write: bool) -> (AllocId, AccessOutcome) {
        let id = r.malloc_managed("x", size);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        let out = r.gpu_access(id, full, write, Ns::ZERO);
        (id, out)
    }

    #[test]
    fn migration_moves_bytes_and_faults() {
        let mut r = UmRuntime::new(&intel_pascal());
        let (_, out) = host_then_gpu(&mut r, 16 * MIB, false);
        assert_eq!(out.h2d_bytes, 16 * MIB);
        assert!(out.fault_stall > Ns::ZERO);
        assert!(out.done > Ns::ZERO);
        assert_eq!(r.metrics.migrated_pages_h2d, 256);
        assert_eq!(r.dev.used(), 16 * MIB);
    }

    #[test]
    fn read_mostly_read_duplicates() {
        let mut r = UmRuntime::new(&intel_pascal());
        let id = r.malloc_managed("x", 4 * MIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.mem_advise(id, full, crate::um::Advise::ReadMostly, Ns::ZERO);
        let out = r.gpu_access(id, full, false, Ns::ZERO);
        assert_eq!(out.h2d_bytes, 4 * MIB, "duplicate copies data");
        let alloc = r.space.get(id);
        assert_eq!(alloc.pages.count(full, |p| p.residency == Residency::Both), 64);
        assert_eq!(r.metrics.duplicated_pages, 64);
        // Host copy still valid: host read is local and free of faults.
        let before = r.metrics.cpu_faults;
        r.host_access(id, full, false, out.done);
        assert_eq!(r.metrics.cpu_faults, before);
    }

    #[test]
    fn gpu_write_collapses_duplicates() {
        let mut r = UmRuntime::new(&intel_pascal());
        let id = r.malloc_managed("x", 4 * MIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.mem_advise(id, full, crate::um::Advise::ReadMostly, Ns::ZERO);
        let o1 = r.gpu_access(id, full, false, Ns::ZERO); // duplicate
        let o2 = r.gpu_access(id, full, true, o1.done); // write -> collapse
        assert!(o2.fault_stall > Ns::ZERO, "invalidation costs driver time");
        assert_eq!(r.metrics.invalidated_pages, 64);
        let alloc = r.space.get(id);
        assert_eq!(alloc.pages.count(full, |p| p.residency == Residency::Device), 64);
    }

    #[test]
    fn pref_host_zero_copy_instead_of_migration() {
        let mut r = UmRuntime::new(&intel_pascal());
        let id = r.malloc_managed("x", 4 * MIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.mem_advise(id, full, crate::um::Advise::PreferredLocation(crate::um::Loc::Cpu), Ns::ZERO);
        let out = r.gpu_access(id, full, false, Ns::ZERO);
        assert_eq!(out.h2d_bytes, 0, "no migration");
        assert_eq!(out.remote_bytes, 4 * MIB, "paid remotely instead");
        assert_eq!(r.dev.used(), 0);
    }

    #[test]
    fn p9_remote_maps_under_pressure_instead_of_evicting() {
        let mut r = UmRuntime::new(&p9_volta());
        let cap = r.dev.capacity();
        let a = r.malloc_managed("a", cap - 64 * MIB);
        let b = r.malloc_managed("b", GIB);
        for id in [a, b] {
            let full = r.space.get(id).full();
            r.host_access(id, full, true, Ns::ZERO);
        }
        let fa = r.space.get(a).full();
        r.gpu_access(a, fa, false, Ns::ZERO); // fills almost all memory
        let evictions_before = r.dev.evictions;
        let fb = r.space.get(b).full();
        let out = r.gpu_access(b, fb, false, Ns::ZERO);
        assert_eq!(r.dev.evictions, evictions_before, "no eviction storm on P9");
        assert!(out.remote_bytes > 0, "overflow served remotely");
        assert!(out.h2d_bytes < GIB, "only the fitting prefix migrated");
    }

    #[test]
    fn intel_evicts_under_pressure_no_remote_option() {
        let mut r = UmRuntime::new(&intel_pascal());
        let cap = r.dev.capacity();
        let a = r.malloc_managed("a", cap - 64 * MIB);
        let b = r.malloc_managed("b", 512 * MIB);
        for id in [a, b] {
            let full = r.space.get(id).full();
            r.host_access(id, full, true, Ns::ZERO);
        }
        let fa = r.space.get(a).full();
        r.gpu_access(a, fa, false, Ns::ZERO);
        let fb = r.space.get(b).full();
        let out = r.gpu_access(b, fb, false, Ns::ZERO);
        assert!(r.dev.evictions > 0, "PCIe platform must evict");
        assert_eq!(out.remote_bytes, 0);
        assert_eq!(out.h2d_bytes, 512 * MIB, "everything migrates");
    }

    #[test]
    fn density_escalation_reduces_fault_groups() {
        let mk = |escalate: bool| {
            let mut plat = intel_pascal();
            plat.um.density_escalation = escalate;
            let mut r = UmRuntime::new(&plat);
            let id = r.malloc_managed("x", 64 * MIB); // 1024 pages
            let full = r.space.get(id).full();
            r.host_access(id, full, true, Ns::ZERO);
            let out = r.gpu_access(id, full, false, Ns::ZERO);
            (r.metrics.gpu_fault_groups, out.fault_stall, out.h2d_bytes)
        };
        let (groups_fixed, stall_fixed, bytes_fixed) = mk(false);
        let (groups_ramp, stall_ramp, bytes_ramp) = mk(true);
        assert!(groups_ramp < groups_fixed / 2, "ramp {groups_ramp} vs fixed {groups_fixed}");
        assert!(stall_ramp < stall_fixed, "fewer groups, less stall");
        assert_eq!(bytes_fixed, bytes_ramp, "same data moved either way");
    }

    #[test]
    fn etc_throttle_caps_eviction_churn_on_p9() {
        // Advised (forced-local) accesses beyond the ETC threshold fall
        // back to remote mapping: churn stops.
        let run_with = |throttle: bool| {
            let mut plat = p9_volta();
            plat.um.etc_throttle = throttle;
            plat.um.etc_threshold = 256 * MIB;
            let mut r = UmRuntime::new(&plat);
            let cap = r.dev.capacity();
            let a = r.malloc_managed("a", cap - 64 * MIB);
            let b = r.malloc_managed("b", 2 * crate::util::units::GIB);
            for id in [a, b] {
                let full = r.space.get(id).full();
                r.host_access(id, full, true, Ns::ZERO);
            }
            let fb0 = r.space.get(b).full();
            r.mem_advise(b, fb0, crate::um::Advise::ReadMostly, Ns::ZERO);
            let fa = r.space.get(a).full();
            r.gpu_access(a, fa, false, Ns::ZERO);
            let out = r.gpu_access(b, fb0, false, Ns::ZERO);
            (r.metrics.evicted_chunks, out.remote_bytes)
        };
        let (evictions_plain, remote_plain) = run_with(false);
        let (evictions_etc, remote_etc) = run_with(true);
        assert!(evictions_etc < evictions_plain, "throttle cuts churn: {evictions_etc} vs {evictions_plain}");
        assert!(remote_etc > remote_plain, "remainder served remotely");
    }

    fn grace_rt() -> UmRuntime {
        UmRuntime::new(&crate::platform::grace_coherent())
    }

    #[test]
    fn coherent_host_access_is_remote_with_no_faults() {
        let mut r = grace_rt();
        let id = r.malloc_managed("x", 4 * MIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        let out = r.gpu_access(id, full, false, Ns::ZERO);
        assert_eq!(r.metrics.gpu_fault_groups, 0, "coherent servicing raises no fault groups");
        assert_eq!(out.fault_stall, Ns::ZERO);
        assert_eq!(out.remote_bytes, 4 * MIB);
        assert_eq!(r.metrics.remote_access_bytes, 4 * MIB);
        assert_eq!(out.h2d_bytes, 0, "one touch is under the threshold: data stays put");
        let alloc = r.space.get(id);
        assert_eq!(alloc.pages.count(full, |p| p.residency == Residency::Host), 64);
    }

    #[test]
    fn counter_threshold_triggers_background_migration() {
        let mut r = grace_rt();
        assert_eq!(r.policy.counter_threshold, 4);
        let id = r.malloc_managed("x", 4 * MIB); // 64 pages = 4 counter groups
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        let mut last = Ns::ZERO;
        for i in 0..4u32 {
            let out = r.gpu_access(id, full, false, last);
            if i < 3 {
                assert_eq!(r.metrics.counter_migrations, 0, "touch {i} is under threshold");
                assert_eq!(out.done, last, "remote service never stalls the access");
            }
            last = out.done;
        }
        assert_eq!(r.metrics.counter_threshold_crossings, 4, "all 4 groups crossed");
        assert_eq!(r.metrics.counter_migrations, 4);
        assert_eq!(r.metrics.migrated_pages_h2d, 64);
        assert_eq!(r.metrics.gpu_fault_groups, 0, "migration is counter-driven, not fault-driven");
        let alloc = r.space.get(id);
        assert_eq!(alloc.pages.count(full, |p| p.residency == Residency::Device), 64);
        assert_eq!(alloc.pages.count(full, |p| p.flags.get(PageFlags::COUNTER_PLACED)), 64);
        // Post-migration the access is a free device hit, and the
        // traffic it no longer sends over the link accrues as the
        // watchdog's coherent benefit signal.
        let out = r.gpu_access(id, full, false, last);
        assert_eq!(out.remote_bytes, 0);
        assert_eq!(out.done, last);
        assert_eq!(r.coherent_avoided_remote, 4 * MIB);
    }

    #[test]
    fn read_mostly_pins_remote_on_coherent() {
        let mut r = grace_rt();
        let id = r.malloc_managed("x", 4 * MIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.mem_advise(id, full, crate::um::Advise::ReadMostly, Ns::ZERO);
        let mut last = Ns::ZERO;
        for _ in 0..10 {
            let out = r.gpu_access(id, full, false, last);
            last = out.done;
            assert_eq!(out.h2d_bytes, 0);
        }
        assert_eq!(r.metrics.counter_migrations, 0, "ReadMostly = pin remote, never migrate");
        assert_eq!(r.metrics.duplicated_pages, 0, "no duplication: the fabric is already coherent");
        assert_eq!(r.metrics.remote_access_bytes, 40 * MIB);
    }

    #[test]
    fn pref_gpu_still_migrates_eagerly_on_coherent() {
        let mut r = grace_rt();
        let id = r.malloc_managed("x", 4 * MIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.mem_advise(id, full, crate::um::Advise::PreferredLocation(crate::um::Loc::Gpu), Ns::ZERO);
        let out = r.gpu_access(id, full, false, Ns::ZERO);
        assert_eq!(out.h2d_bytes, 4 * MIB, "explicit placement overrides the counter path");
        assert_eq!(r.metrics.counter_migrations, 0);
        assert!(r.metrics.gpu_fault_groups > 0, "explicit migration still pays the driver");
    }

    #[test]
    fn coherent_counters_reset_with_run_state() {
        let mut r = grace_rt();
        let id = r.malloc_managed("x", MIB); // 16 pages = exactly 1 group
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        for _ in 0..3 {
            r.gpu_access(id, full, false, Ns::ZERO);
        }
        assert!(!r.counter_touches.is_empty());
        r.reset_run_state();
        assert!(r.counter_touches.is_empty());
        assert_eq!(r.coherent_avoided_remote, 0);
        // The same sequence replays identically after reset: three
        // touches stay remote, the fourth crosses and migrates.
        r.host_access(id, full, true, Ns::ZERO);
        for _ in 0..3 {
            r.gpu_access(id, full, false, Ns::ZERO);
        }
        assert_eq!(r.metrics.counter_migrations, 0);
        r.gpu_access(id, full, false, Ns::ZERO);
        assert_eq!(r.metrics.counter_migrations, 1);
    }

    #[test]
    fn read_mostly_forces_local_even_under_pressure_on_p9() {
        let mut r = UmRuntime::new(&p9_volta());
        let cap = r.dev.capacity();
        let a = r.malloc_managed("a", cap - 64 * MIB);
        let b = r.malloc_managed("b", GIB);
        for id in [a, b] {
            let full = r.space.get(id).full();
            r.host_access(id, full, true, Ns::ZERO);
        }
        let fb0 = r.space.get(b).full();
        r.mem_advise(b, fb0, crate::um::Advise::ReadMostly, Ns::ZERO);
        let fa = r.space.get(a).full();
        r.gpu_access(a, fa, false, Ns::ZERO);
        let out = r.gpu_access(b, fb0, false, Ns::ZERO);
        assert!(r.dev.evictions > 0, "advise forces duplication -> eviction");
        assert_eq!(out.h2d_bytes, GIB, "whole advised range migrated");
    }
}
