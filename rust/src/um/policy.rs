//! UM driver policy knobs and the public advise/location enums.
//!
//! Defaults model the CUDA 10.1 driver on Pascal/Volta as characterized
//! by Sakharnykh (GTC'17, "Unified Memory on Pascal and Volta") and the
//! paper's §II. Per-platform overrides (fault latencies) live in
//! `platform::calibration`.

use crate::sim::inject::InjectConfig;
use crate::util::units::{Bytes, Ns, KIB, MIB};

use super::auto::PredictorKind;

/// Which policy drives eviction victim selection under oversubscription
/// (the `--evictor` CLI knob; see `docs/EVICTION.md`).
#[derive(Clone, Copy, Debug, Default, PartialEq, Eq)]
pub enum EvictorKind {
    /// The driver's raw LRU over 2 MiB chunks — the paper's §II-D
    /// behaviour and the default. Byte-identical to the pre-knob
    /// runtime (pinned by `rust/tests/evictor_modes.rs`).
    #[default]
    Lru,
    /// LRU biased by the `um::auto` learned ranker: ranked
    /// predicted-dead chunks are evicted first, predicted-live chunks
    /// are deferred, and predicted-dead clean duplicates are pre-dropped
    /// ahead of the watermark path. Falls back to plain LRU whenever no
    /// engine hints exist (every non-`UM Auto` variant).
    Learned,
}

impl EvictorKind {
    /// CLI name.
    pub fn name(self) -> &'static str {
        match self {
            EvictorKind::Lru => "lru",
            EvictorKind::Learned => "learned",
        }
    }

    /// Parse a CLI value (`lru` | `learned`).
    pub fn parse(s: &str) -> Option<EvictorKind> {
        match s.to_ascii_lowercase().as_str() {
            "lru" | "driver" => Some(EvictorKind::Lru),
            "learned" | "ranked" => Some(EvictorKind::Learned),
            _ => None,
        }
    }

    /// Stable wire code (`.umt` replay section).
    pub fn code(self) -> u8 {
        match self {
            EvictorKind::Lru => 0,
            EvictorKind::Learned => 1,
        }
    }

    pub fn from_code(c: u8) -> Option<EvictorKind> {
        match c {
            0 => Some(EvictorKind::Lru),
            1 => Some(EvictorKind::Learned),
            _ => None,
        }
    }
}

/// `cudaMemAdvise` advice values (paper §II-B).
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Advise {
    /// `cudaMemAdviseSetReadMostly`: duplicate on read fault.
    ReadMostly,
    /// `cudaMemAdviseSetPreferredLocation(loc)`: pin pages to `loc`.
    PreferredLocation(Loc),
    /// `cudaMemAdviseSetAccessedBy(loc)`: map remotely into `loc`.
    AccessedBy(Loc),
    /// The paired `Unset` calls.
    UnsetReadMostly,
    UnsetPreferredLocation,
    UnsetAccessedBy(Loc),
}

impl Advise {
    /// Stable wire code (`.umt` replay section): the full advise ×
    /// location product packed into one byte, so a decoded capture
    /// re-encodes canonically with no alias ambiguity.
    pub fn code(self) -> u8 {
        match self {
            Advise::ReadMostly => 0,
            Advise::PreferredLocation(Loc::Cpu) => 1,
            Advise::PreferredLocation(Loc::Gpu) => 2,
            Advise::AccessedBy(Loc::Cpu) => 3,
            Advise::AccessedBy(Loc::Gpu) => 4,
            Advise::UnsetReadMostly => 5,
            Advise::UnsetPreferredLocation => 6,
            Advise::UnsetAccessedBy(Loc::Cpu) => 7,
            Advise::UnsetAccessedBy(Loc::Gpu) => 8,
        }
    }

    pub fn from_code(c: u8) -> Option<Advise> {
        match c {
            0 => Some(Advise::ReadMostly),
            1 => Some(Advise::PreferredLocation(Loc::Cpu)),
            2 => Some(Advise::PreferredLocation(Loc::Gpu)),
            3 => Some(Advise::AccessedBy(Loc::Cpu)),
            4 => Some(Advise::AccessedBy(Loc::Gpu)),
            5 => Some(Advise::UnsetReadMostly),
            6 => Some(Advise::UnsetPreferredLocation),
            7 => Some(Advise::UnsetAccessedBy(Loc::Cpu)),
            8 => Some(Advise::UnsetAccessedBy(Loc::Gpu)),
            _ => None,
        }
    }
}

/// A processor / memory location.
#[derive(Clone, Copy, Debug, PartialEq, Eq)]
pub enum Loc {
    Cpu,
    Gpu,
}

impl Loc {
    /// Stable wire code (`.umt` replay section).
    pub fn code(self) -> u8 {
        match self {
            Loc::Cpu => 0,
            Loc::Gpu => 1,
        }
    }

    pub fn from_code(c: u8) -> Option<Loc> {
        match c {
            0 => Some(Loc::Cpu),
            1 => Some(Loc::Gpu),
            _ => None,
        }
    }
}

/// Driver policy parameters.
#[derive(Clone, Copy, Debug)]
pub struct UmPolicy {
    /// Service time for one GPU fault group (driver occupancy):
    /// interrupt, fault buffer read, dedup, page-table updates.
    pub fault_group_base: Ns,
    /// Additional service time per 64 KiB page in the group.
    pub fault_per_page: Ns,
    /// Pages the driver migrates per fault group for *unadvised* memory.
    /// The density prefetcher starts at one 64 KiB block and escalates;
    /// 8 pages (512 KiB) is the observed average batch mid-stream.
    pub fault_group_pages: u32,
    /// Pages per group once `PreferredLocation(Gpu)` told the driver the
    /// range is wanted on-device: full 2 MiB escalation immediately.
    pub advised_group_pages: u32,
    /// Fault-service discount for advised ranges (the driver skips its
    /// placement heuristics; paper §IV-A observes "page fault handling
    /// becomes more efficient when the advises are applied").
    pub advised_fault_discount: f64,
    /// Multiplier on fault-group count for massively-parallel first
    /// touch (duplicated faults from many warps, §II-A / [18]).
    pub dup_fault_factor: f64,
    /// First-touch population (no data movement) relative service cost.
    pub populate_discount: f64,
    /// Cost of collapsing a ReadMostly duplicate on write (invalidation
    /// broadcast + page-table updates), per invalidated range.
    pub invalidation_cost: Ns,
    /// CPU-side page-fault service time (OS + driver round trip).
    pub cpu_fault_cost: Ns,
    /// Chunk size for `cudaMemPrefetchAsync` internal splitting.
    pub prefetch_chunk: Bytes,
    /// Enable pre-eviction (related-work [3] ablation): keep this many
    /// bytes free by evicting ahead of demand. 0 disables.
    pub preevict_watermark: Bytes,
    /// On coherent (ATS) platforms the driver services faults on
    /// host-resident pages by *remote mapping* instead of migration once
    /// the device is under memory pressure, avoiding eviction storms.
    /// (NVLink/P9 behaviour; PCIe platforms cannot.)
    pub remote_map_under_pressure: bool,
    /// Density-based escalation (the driver's tree prefetcher,
    /// Sakharnykh GTC'17 / Ganguly et al. [3]): during a streaming
    /// fault sequence the migration granule ramps from
    /// `fault_group_pages` up to `advised_group_pages` as density
    /// accumulates, instead of staying fixed. Default off: the fixed
    /// batch is calibrated as the ramp's average; this flag exposes the
    /// mechanism for the `ablate_density` study.
    pub density_escalation: bool,
    /// ETC-style thrash throttling (Li et al., ASPLOS'19 [10]): once an
    /// access has evicted more than `etc_threshold` bytes, the driver
    /// stops forcing locality and serves the remainder by remote
    /// mapping (coherent platforms). Default off — the paper's testbed
    /// driver has no such mitigation; the `ablate_etc` study shows it
    /// rescuing the P9 oversubscription pathology.
    pub etc_throttle: bool,
    /// Eviction-bytes-per-access threshold for the ETC throttle.
    pub etc_threshold: Bytes,
    /// Which predictive-prefetch engine `UmRuntime::enable_auto`
    /// attaches for the `UM Auto` variant (the `--predictor` CLI knob):
    /// the learned delta-history tables (default) or the original
    /// pattern-classifier rule. Ignored by every other variant.
    pub auto_predictor: PredictorKind,
    /// Eviction victim-selection policy (the `--evictor` CLI knob):
    /// raw chunk LRU (default, the paper's driver behaviour) or LRU
    /// biased by the `um::auto` learned dead-range ranker. `Learned`
    /// only changes behaviour when the engine supplies hints (the
    /// `UM Auto` variant); see `docs/EVICTION.md`.
    pub evictor: EvictorKind,
    /// Fault-injection scenario (the chaos layer; `docs/ROBUSTNESS.md`).
    /// Default `Off`: no hook fires and the runtime is byte-identical
    /// to the un-instrumented behaviour (pinned by
    /// `rust/tests/chaos_determinism.rs`).
    pub inject: InjectConfig,
    /// Hardware-coherent system memory (Grace-Hopper-class, NVLink-C2C;
    /// `docs/PLATFORMS.md`): GPU accesses to host-resident managed pages
    /// are serviced remotely at cache-line granularity with **no fault
    /// groups**, and placement is driven by the per-page-group access
    /// counter below instead of the fault path. Default false — the
    /// three migration-based platforms never set it, which keeps them
    /// byte-identical (pinned by `rust/tests/platform_oracle.rs`).
    pub coherent: bool,
    /// Pages per hardware access-counter group on the coherent
    /// platform (counter granularity; GH counters track ~2 MiB regions,
    /// 16 × 64 KiB pages here). Ignored unless `coherent`.
    pub counter_group_pages: u32,
    /// Remote-access touches a counter group accumulates before the
    /// driver migrates the group's touched host pages to the device in
    /// the background. 0 disables counter migration entirely ("pin
    /// remote, never migrate" — also what `ReadMostly` maps to on the
    /// coherent platform). Ignored unless `coherent`.
    pub counter_threshold: u32,
}

impl Default for UmPolicy {
    fn default() -> Self {
        UmPolicy {
            fault_group_base: Ns::from_us(30.0),
            fault_per_page: Ns::from_us(1.5),
            fault_group_pages: 8,
            advised_group_pages: 32,
            advised_fault_discount: 0.55,
            dup_fault_factor: 1.25,
            populate_discount: 0.30,
            invalidation_cost: Ns::from_us(15.0),
            cpu_fault_cost: Ns::from_us(12.0),
            prefetch_chunk: 4 * MIB,
            preevict_watermark: 0,
            remote_map_under_pressure: false,
            density_escalation: false,
            etc_throttle: false,
            etc_threshold: 512 * MIB,
            auto_predictor: PredictorKind::Learned,
            evictor: EvictorKind::Lru,
            inject: InjectConfig::default(),
            coherent: false,
            counter_group_pages: 16,
            counter_threshold: 0,
        }
    }
}

impl UmPolicy {
    /// Effective pages-per-group. Only `PreferredLocation(Gpu)` buys the
    /// full 2 MiB escalation (`placed == true`): the driver knows the
    /// whole range belongs on the device. `ReadMostly` duplication
    /// faults migrate at the default batch — the driver only duplicates
    /// what is actually read.
    pub fn group_pages(&self, placed: bool) -> u32 {
        if placed {
            self.advised_group_pages
        } else {
            self.fault_group_pages
        }
    }

    /// Service time of one fault group covering `pages` pages.
    /// `advised` (any placement/duplication advise) skips the driver's
    /// placement heuristics — cheaper service.
    pub fn fault_service(&self, pages: u32, advised: bool) -> Ns {
        let raw = self.fault_group_base + self.fault_per_page * pages as u64;
        if advised {
            raw.scale(self.advised_fault_discount)
        } else {
            raw
        }
    }

    /// Sanity-check invariants (used by property tests).
    pub fn validate(&self) -> Result<(), String> {
        if self.fault_group_pages == 0 || self.advised_group_pages == 0 {
            return Err("group pages must be positive".into());
        }
        if !(0.0..=1.0).contains(&self.advised_fault_discount) {
            return Err("advised_fault_discount out of [0,1]".into());
        }
        if self.dup_fault_factor < 1.0 {
            return Err("dup_fault_factor < 1".into());
        }
        if self.prefetch_chunk < 64 * KIB {
            return Err("prefetch chunk below page size".into());
        }
        if self.coherent && self.counter_group_pages == 0 {
            return Err("counter_group_pages must be positive on a coherent platform".into());
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn default_policy_valid() {
        UmPolicy::default().validate().unwrap();
    }

    #[test]
    fn advised_faults_cheaper_and_bigger() {
        let p = UmPolicy::default();
        assert!(p.group_pages(true) > p.group_pages(false));
        let unadv = p.fault_service(8, false);
        let adv = p.fault_service(8, true);
        assert!(adv < unadv, "advised {adv} >= unadvised {unadv}");
    }

    #[test]
    fn fault_service_scales_with_pages() {
        let p = UmPolicy::default();
        assert!(p.fault_service(32, false) > p.fault_service(1, false));
    }

    #[test]
    fn evictor_kind_parse_roundtrip() {
        for k in [EvictorKind::Lru, EvictorKind::Learned] {
            assert_eq!(EvictorKind::parse(k.name()), Some(k));
        }
        assert_eq!(EvictorKind::default(), EvictorKind::Lru, "lru is the pre-knob behaviour");
        assert_eq!(UmPolicy::default().evictor, EvictorKind::Lru);
        assert_eq!(EvictorKind::parse("bogus"), None);
    }

    #[test]
    fn coherent_knobs_default_inert() {
        // The migration-based platforms never set these; the defaults
        // must leave the runtime byte-identical to the pre-coherent
        // behaviour (platform_oracle.rs pins the end-to-end version).
        let p = UmPolicy::default();
        assert!(!p.coherent);
        assert_eq!(p.counter_threshold, 0, "counter migration disabled by default");
        assert!(p.counter_group_pages > 0);
        let mut bad = UmPolicy::default();
        bad.coherent = true;
        bad.counter_group_pages = 0;
        assert!(bad.validate().is_err());
    }

    #[test]
    fn validation_catches_bad_values() {
        let mut p = UmPolicy::default();
        p.fault_group_pages = 0;
        assert!(p.validate().is_err());
        let mut p = UmPolicy::default();
        p.dup_fault_factor = 0.5;
        assert!(p.validate().is_err());
        let mut p = UmPolicy::default();
        p.prefetch_chunk = 1024;
        assert!(p.validate().is_err());
    }
}
