//! The paper's three test platforms as parameter sets (§III-B), plus a
//! Grace-Hopper-class coherent fourth (arxiv 2407.07850; see
//! `docs/PLATFORMS.md`).
//!
//! | | CPU | GPU | GPU mem | link |
//! |---|---|---|---|---|
//! | Intel-Pascal | i7-7820X, 32 GB | GTX 1050 Ti | 4 GB | PCIe 3.0 |
//! | Intel-Volta | Xeon 6132, 192 GB | Tesla V100 | 16 GB | PCIe 3.0 |
//! | P9-Volta | Power9, 256 GB | Tesla V100 | 16 GB | NVLink 2.0 |
//! | Grace-Coherent | Grace-class | GH200 (H100-class) | 16 GB* | NVLink-C2C |
//!
//! *The coherent platform's device capacity is deliberately normalized
//! to the V100-class 16 GiB so the three-generation comparison
//! (`fig_coherent`) contrasts *interconnects* at identical footprints —
//! not the 96 GB a real GH200 ships with. `docs/PLATFORMS.md` records
//! what is and is not reproduced.
//!
//! Calibration provenance is documented per constant in [`calibration`].

pub mod calibration;

use crate::mem::interconnect::Link;
use crate::um::policy::UmPolicy;
use crate::util::units::{Bytes, GIB};

/// GPU compute/memory capability.
#[derive(Clone, Copy, Debug)]
pub struct GpuSpec {
    pub name: &'static str,
    /// Physical device memory.
    pub mem_capacity: Bytes,
    /// Device memory reserved by the CUDA context/runtime (not usable
    /// for UM data). Oversubscription thresholds use usable capacity.
    pub reserved: Bytes,
    /// Peak FP32 throughput, FLOP/s.
    pub flops_f32: f64,
    /// Device memory bandwidth, bytes/s.
    pub mem_bw: f64,
    /// Streaming multiprocessors (scales fault parallelism effects).
    pub sm_count: u32,
}

impl GpuSpec {
    pub fn usable(&self) -> Bytes {
        self.mem_capacity - self.reserved
    }
}

/// A complete platform description.
#[derive(Clone, Copy, Debug)]
pub struct PlatformSpec {
    pub name: &'static str,
    pub gpu: GpuSpec,
    pub link: Link,
    /// Coherent CPU access to GPU memory (ATS over NVLink on P9). On
    /// PCIe platforms the CPU cannot touch device memory (§IV-A: "On
    /// Power9 it is possible for the CPU to access GPU memory while this
    /// is not possible on Intel platforms").
    pub cpu_can_access_gpu: bool,
    /// GPU mapping of host memory (zero-copy) — true on all platforms.
    pub gpu_can_access_host: bool,
    /// Effective host memory copy bandwidth (memcpy on the host).
    pub host_mem_bw: f64,
    /// UM driver policy (fault costs etc.) for this platform.
    pub um: UmPolicy,
}

/// Platform identifiers used across the CLI/bench harness.
#[derive(Clone, Copy, Debug, PartialEq, Eq, Hash)]
pub enum PlatformId {
    IntelPascal,
    IntelVolta,
    P9Volta,
    /// Grace-Hopper-class hardware-coherent system (NVLink-C2C): no
    /// fault-driven migration — line-grained remote access plus
    /// access-counter placement. See `docs/PLATFORMS.md`.
    GraceCoherent,
}

impl PlatformId {
    pub const ALL: [PlatformId; 4] = [
        PlatformId::IntelPascal,
        PlatformId::IntelVolta,
        PlatformId::P9Volta,
        PlatformId::GraceCoherent,
    ];

    pub fn spec(self) -> PlatformSpec {
        match self {
            PlatformId::IntelPascal => intel_pascal(),
            PlatformId::IntelVolta => intel_volta(),
            PlatformId::P9Volta => p9_volta(),
            PlatformId::GraceCoherent => grace_coherent(),
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            PlatformId::IntelPascal => "Intel-Pascal",
            PlatformId::IntelVolta => "Intel-Volta",
            PlatformId::P9Volta => "P9-Volta",
            PlatformId::GraceCoherent => "Grace-Coherent",
        }
    }

    pub fn parse(s: &str) -> Option<PlatformId> {
        match s.to_ascii_lowercase().as_str() {
            "intel-pascal" | "intelpascal" | "pascal" => Some(PlatformId::IntelPascal),
            "intel-volta" | "intelvolta" | "volta" => Some(PlatformId::IntelVolta),
            "p9-volta" | "p9volta" | "p9" | "power9" => Some(PlatformId::P9Volta),
            "grace-coherent" | "gracecoherent" | "grace" | "gh200" => Some(PlatformId::GraceCoherent),
            _ => None,
        }
    }

    /// Stable wire code (`.umt` replay section).
    pub fn code(self) -> u8 {
        match self {
            PlatformId::IntelPascal => 0,
            PlatformId::IntelVolta => 1,
            PlatformId::P9Volta => 2,
            PlatformId::GraceCoherent => 3,
        }
    }

    pub fn from_code(c: u8) -> Option<PlatformId> {
        match c {
            0 => Some(PlatformId::IntelPascal),
            1 => Some(PlatformId::IntelVolta),
            2 => Some(PlatformId::P9Volta),
            3 => Some(PlatformId::GraceCoherent),
            _ => None,
        }
    }

    /// The paper's original §III-B testbeds (excludes the coherent
    /// extension platform). Suite defaults and the paper-figure matrix
    /// iterate `ALL`; code that must reproduce the paper exactly as
    /// published iterates this.
    pub const PAPER: [PlatformId; 3] =
        [PlatformId::IntelPascal, PlatformId::IntelVolta, PlatformId::P9Volta];

    /// Does this platform service GPU accesses to host memory through
    /// hardware coherence (no fault groups, counter-driven placement)?
    pub fn is_coherent(self) -> bool {
        self.spec().um.coherent
    }
}

/// Intel Core i7-7820X + GeForce GTX 1050 Ti (4 GB) over PCIe 3.0.
pub fn intel_pascal() -> PlatformSpec {
    PlatformSpec {
        name: "Intel-Pascal",
        gpu: GpuSpec {
            name: "GTX 1050 Ti",
            mem_capacity: 4 * GIB,
            reserved: calibration::CTX_RESERVED_SMALL,
            flops_f32: calibration::GTX1050TI_FLOPS,
            mem_bw: calibration::GTX1050TI_MEM_BW,
            sm_count: 6,
        },
        link: Link::pcie3_x16(),
        cpu_can_access_gpu: false,
        gpu_can_access_host: true,
        host_mem_bw: calibration::HOST_BW_INTEL_DESKTOP,
        um: UmPolicy {
            fault_group_base: calibration::FAULT_BASE_INTEL,
            remote_map_under_pressure: false,
            ..UmPolicy::default()
        },
    }
}

/// Intel Xeon Gold 6132 + Tesla V100 (16 GB) over PCIe 3.0 (Kebnekaise).
pub fn intel_volta() -> PlatformSpec {
    PlatformSpec {
        name: "Intel-Volta",
        gpu: GpuSpec {
            name: "Tesla V100",
            mem_capacity: 16 * GIB,
            reserved: calibration::CTX_RESERVED_LARGE,
            flops_f32: calibration::V100_FLOPS,
            mem_bw: calibration::V100_MEM_BW,
            sm_count: 80,
        },
        link: Link::pcie3_x16(),
        cpu_can_access_gpu: false,
        gpu_can_access_host: true,
        host_mem_bw: calibration::HOST_BW_XEON,
        um: UmPolicy {
            fault_group_base: calibration::FAULT_BASE_INTEL,
            remote_map_under_pressure: false,
            ..UmPolicy::default()
        },
    }
}

/// IBM Power9 + Tesla V100 (16 GB) over NVLink 2.0 (Lassen-like).
pub fn p9_volta() -> PlatformSpec {
    PlatformSpec {
        name: "P9-Volta",
        gpu: GpuSpec {
            name: "Tesla V100",
            mem_capacity: 16 * GIB,
            reserved: calibration::CTX_RESERVED_LARGE,
            flops_f32: calibration::V100_FLOPS,
            mem_bw: calibration::V100_MEM_BW,
            sm_count: 80,
        },
        link: Link::nvlink2_p9(),
        cpu_can_access_gpu: true,
        gpu_can_access_host: true,
        host_mem_bw: calibration::HOST_BW_P9,
        um: UmPolicy {
            fault_group_base: calibration::FAULT_BASE_P9,
            remote_map_under_pressure: true,
            ..UmPolicy::default()
        },
    }
}

/// Grace-Hopper-class coherent superchip (GH200-like) over NVLink-C2C.
///
/// Deliberate modeling choices (documented in `docs/PLATFORMS.md`):
/// device capacity is normalized to the V100-class 16 GiB — not the
/// real 96 GB — so `fig_coherent` compares interconnect generations at
/// identical footprints and the paper's 80%/150% regimes stay inside
/// `calibration::MAX_FOOTPRINT`. Compute/bandwidth are H100-class, so
/// the "fast GPU starved by the data path" effect from the
/// Pascal→Volta contrast carries forward another generation.
pub fn grace_coherent() -> PlatformSpec {
    PlatformSpec {
        name: "Grace-Coherent",
        gpu: GpuSpec {
            name: "GH200 (H100-class)",
            mem_capacity: 16 * GIB,
            reserved: calibration::CTX_RESERVED_LARGE,
            flops_f32: calibration::GH200_FLOPS,
            mem_bw: calibration::GH200_MEM_BW,
            sm_count: 132,
        },
        link: Link::c2c_grace(),
        cpu_can_access_gpu: true,
        gpu_can_access_host: true,
        host_mem_bw: calibration::HOST_BW_GRACE,
        um: UmPolicy {
            fault_group_base: calibration::FAULT_BASE_GRACE,
            remote_map_under_pressure: true,
            coherent: true,
            counter_group_pages: 16,
            counter_threshold: 4,
            ..UmPolicy::default()
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::interconnect::TransferMode;

    #[test]
    fn all_platforms_have_valid_policies() {
        for id in PlatformId::ALL {
            id.spec().um.validate().unwrap();
        }
    }

    #[test]
    fn capability_matrix_matches_paper() {
        assert!(!intel_pascal().cpu_can_access_gpu);
        assert!(!intel_volta().cpu_can_access_gpu);
        assert!(p9_volta().cpu_can_access_gpu);
        assert!(grace_coherent().cpu_can_access_gpu);
        for id in PlatformId::ALL {
            assert!(id.spec().gpu_can_access_host);
        }
        // remote-map-under-pressure tracks ATS coherence
        assert!(p9_volta().um.remote_map_under_pressure);
        assert!(!intel_pascal().um.remote_map_under_pressure);
        // Hardware coherence is exclusive to the C2C generation: the
        // paper's three testbeds all migrate on fault.
        for id in PlatformId::PAPER {
            assert!(!id.is_coherent(), "{} must stay fault-driven", id.name());
        }
        assert!(PlatformId::GraceCoherent.is_coherent());
        assert!(grace_coherent().um.counter_threshold > 0, "counter migration on by default");
    }

    #[test]
    fn memory_capacities() {
        assert_eq!(intel_pascal().gpu.mem_capacity, 4 * GIB);
        assert_eq!(intel_volta().gpu.mem_capacity, 16 * GIB);
        assert_eq!(p9_volta().gpu.mem_capacity, 16 * GIB);
        // Deliberately normalized (not the real 96 GB): identical
        // footprints across interconnect generations; see module docs.
        assert_eq!(grace_coherent().gpu.mem_capacity, 16 * GIB);
        for id in PlatformId::ALL {
            let g = id.spec().gpu;
            assert!(g.usable() > g.mem_capacity / 2);
        }
    }

    #[test]
    fn p9_link_dominates_pcie() {
        let p9 = p9_volta();
        let iv = intel_volta();
        assert!(p9.link.effective_bw(TransferMode::Bulk) > 4.0 * iv.link.effective_bw(TransferMode::Bulk));
    }

    #[test]
    fn parse_roundtrip() {
        for id in PlatformId::ALL {
            assert_eq!(PlatformId::parse(id.name()), Some(id));
        }
        assert_eq!(PlatformId::parse("p9"), Some(PlatformId::P9Volta));
        assert_eq!(PlatformId::parse("nope"), None);
    }

    #[test]
    fn grace_link_dominates_both_prior_generations() {
        let gc = grace_coherent();
        let p9 = p9_volta();
        assert!(gc.link.effective_bw(TransferMode::Bulk) > 4.0 * p9.link.effective_bw(TransferMode::Bulk));
        // The qualitative flip: remote access on C2C beats *bulk DMA*
        // on NVLink 2 — staying put becomes viable.
        assert!(gc.link.remote_bw > p9.link.effective_bw(TransferMode::Bulk));
    }

    #[test]
    fn paper_subset_is_all_minus_coherent() {
        assert_eq!(PlatformId::PAPER.len() + 1, PlatformId::ALL.len());
        for id in PlatformId::PAPER {
            assert!(PlatformId::ALL.contains(&id));
        }
        assert!(!PlatformId::PAPER.contains(&PlatformId::GraceCoherent));
    }

    #[test]
    fn wire_codes_stable() {
        // Codes are a serialization contract (.umt captures in
        // corpora/): appending GraceCoherent as 3 must not renumber.
        assert_eq!(PlatformId::IntelPascal.code(), 0);
        assert_eq!(PlatformId::IntelVolta.code(), 1);
        assert_eq!(PlatformId::P9Volta.code(), 2);
        assert_eq!(PlatformId::GraceCoherent.code(), 3);
        for id in PlatformId::ALL {
            assert_eq!(PlatformId::from_code(id.code()), Some(id));
        }
    }

    #[test]
    fn volta_flops_dwarf_pascal_budget() {
        // V100 vs 1050Ti compute ratio drives the "UM overhead looks
        // worse on Volta" effect (migration time stays similar while
        // compute shrinks).
        assert!(intel_volta().gpu.flops_f32 / intel_pascal().gpu.flops_f32 > 5.0);
    }
}
