//! Calibration constants with provenance notes.
//!
//! None of these targets absolute fidelity to the authors' testbeds —
//! the reproduction validates *shapes* (who wins, by what factor, where
//! crossovers sit; see EXPERIMENTS.md). Each constant cites the public
//! source it is derived from.

use crate::util::units::{Bytes, Ns, GIB, MIB};

/// GTX 1050 Ti: 768 CUDA cores @ ~1.4 GHz boost ≈ 2.1 TFLOP/s FP32
/// (NVIDIA product page).
pub const GTX1050TI_FLOPS: f64 = 2.1e12;

/// GTX 1050 Ti: 128-bit GDDR5 @ 7 Gbps = 112 GB/s.
pub const GTX1050TI_MEM_BW: f64 = 112.0e9;

/// Tesla V100 (SXM2/PCIe averaged): ~14 TFLOP/s FP32 (V100 whitepaper).
pub const V100_FLOPS: f64 = 14.0e12;

/// Tesla V100: 900 GB/s HBM2 (V100 whitepaper).
pub const V100_MEM_BW: f64 = 900.0e9;

/// CUDA context + driver reservation on a small consumer card. A 4 GB
/// 1050 Ti typically exposes ~3.6-3.8 GB to applications.
pub const CTX_RESERVED_SMALL: Bytes = 300 * MIB;

/// Context reservation on a 16 GB V100 (~0.5 GB).
pub const CTX_RESERVED_LARGE: Bytes = 512 * MIB;

/// GPU fault-group service time, Intel/PCIe platforms. Sakharnykh
/// (GTC'17) and Zheng et al. (HPCA'16) report 20-50 us per fault
/// round-trip through the driver over PCIe.
pub const FAULT_BASE_INTEL: Ns = Ns(35_000);

/// Fault-group service on P9/NVLink: shorter driver round-trip (lower
/// latency link, no PCIe config cycles); GTC'18 UM talks show faster
/// fault drains on P9.
pub const FAULT_BASE_P9: Ns = Ns(22_000);

/// Host memcpy effective bandwidth, desktop Skylake-X (i7-7820X, quad
/// channel DDR4-2666, single-thread memcpy ≈ 12-15 GB/s; we model the
/// benchmark's single-threaded init/verify loops).
pub const HOST_BW_INTEL_DESKTOP: f64 = 13.0e9;

/// Host memcpy bandwidth, Xeon Gold 6132 node.
pub const HOST_BW_XEON: f64 = 15.0e9;

/// Host memcpy bandwidth, Power9 (higher per-thread stream bw).
pub const HOST_BW_P9: f64 = 18.0e9;

/// H100-class GPU on a Grace-Hopper superchip: ~67 TFLOP/s FP32
/// (H100 SXM whitepaper, non-tensor FP32).
pub const GH200_FLOPS: f64 = 67.0e12;

/// H100-class HBM3 bandwidth, ~4 TB/s (arxiv 2407.07850 measures
/// 3.4-4.0 TB/s with STREAM-like kernels).
pub const GH200_MEM_BW: f64 = 4.0e12;

/// Fault-group service on the coherent C2C platform. Faults are rare
/// there (line-grained coherent access needs none), but first-touch
/// population and explicitly migrated pages still pay a driver
/// round-trip; the low-latency C2C fabric makes it the shortest of the
/// three generations.
pub const FAULT_BASE_GRACE: Ns = Ns(15_000);

/// Host memcpy bandwidth on Grace (LPDDR5X, ~500 GB/s aggregate;
/// single-threaded init/verify loops see a fraction of that).
pub const HOST_BW_GRACE: f64 = 40.0e9;

/// Default problem-size fractions of *usable* GPU memory (§III-B: "80%
/// and 150% to GPU memory, respectively").
pub const IN_MEMORY_FRACTION: f64 = 0.80;
pub const OVERSUB_FRACTION: f64 = 1.50;

/// Largest single benchmark footprint we simulate (safety rail for the
/// page-table allocation; 26 GB paper max → 32 GiB cap).
pub const MAX_FOOTPRINT: Bytes = 32 * GIB;

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn fractions_match_paper() {
        assert!((IN_MEMORY_FRACTION - 0.8).abs() < f64::EPSILON);
        assert!((OVERSUB_FRACTION - 1.5).abs() < f64::EPSILON);
    }

    #[test]
    fn fault_cost_ordering() {
        // P9's driver round trip is faster, but the same order; the
        // C2C fabric shortens it again without changing the order of
        // magnitude.
        assert!(FAULT_BASE_P9 < FAULT_BASE_INTEL);
        assert!(FAULT_BASE_P9 > Ns(10_000));
        assert!(FAULT_BASE_GRACE < FAULT_BASE_P9);
        assert!(FAULT_BASE_GRACE > Ns(5_000));
    }

    #[test]
    fn v100_roofline_sane() {
        // arithmetic intensity crossover ~ 15.5 flop/byte
        let ai = V100_FLOPS / V100_MEM_BW;
        assert!(ai > 10.0 && ai < 25.0);
    }
}
