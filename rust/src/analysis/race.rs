//! Pass 2: happens-before race detection over the per-stream verb
//! timelines (`vet.race.*`).
//!
//! The detector assigns every data access a vector clock and reports
//! cross-timeline overlapping page ranges with at least one write and
//! no ordering path between them — the trace-level analogue of a
//! dynamic data-race detector, computed without executing anything.
//!
//! ## Timelines and ordering edges
//!
//! Clock slots: slot 0 is the **host timeline** (the program-order
//! sequence of host-side verbs); slot `s + 1` is device stream `s`
//! (stream 0 = default compute, stream 1 = background prefetch,
//! streams 2.. = the extra compute streams the `--streams` knob
//! rotates across). The edges mirror the executor
//! ([`crate::apps::AppCtx`]) exactly:
//!
//! * **Host verbs** (`HostWrite`/`HostRead`/`Memcpy*`) run on the host
//!   timeline and *block on the default stream* (the executor starts
//!   them at `now(DEFAULT)`), so each one joins stream 0's clock —
//!   host access after a default-stream kernel is ordered, host access
//!   after another stream's kernel is **not**.
//! * **Launches** round-robin `launch_index % streams` onto compute
//!   streams (stream 0, then 2, 3, …) and join the host clock at
//!   issue: a kernel observes every host verb issued before it. The
//!   reverse does not hold — later host verbs are not ordered after
//!   the kernel unless a sync intervenes.
//! * **`PrefetchBackground`** runs on stream 1 and gates the *next*
//!   launch (any stream): the executor makes that kernel wait for the
//!   prefetch, a real ordering edge.
//! * **`DeviceSync`** joins every timeline into the host clock — the
//!   global barrier.
//!
//! Two accesses race iff they are on different timelines, overlap in
//! pages of the same allocation, at least one writes, and neither
//! clock dominates the other. Both write → [`super::RACE_WW`]; exactly
//! one writes → [`super::RACE_RW`]. Reports are deduplicated per
//! (code, allocation, timeline pair): the first racing pair is shown,
//! not every combination along two long racing walks.

use std::collections::HashSet;

use crate::gpu::stream::StreamId;
use crate::mem::PageRange;
use crate::trace::replay::{ReplayOp, ReplayProgram};
use crate::util::units::Bytes;

use super::{Diagnostic, Severity, RACE_RW, RACE_WW};

/// One recorded data access with its vector-clock snapshot.
struct Acc {
    op: usize,
    /// Clock slot (0 = host, `s + 1` = device stream `s`).
    slot: usize,
    alloc: u32,
    range: PageRange,
    writes: bool,
}

pub(super) fn check(prog: &ReplayProgram, out: &mut Vec<Diagnostic>) {
    let streams = prog.streams.max(1) as usize;
    // Stream ids in use: 0 (default) and 1 (background) always exist;
    // extra compute streams get ids 2..=streams.
    let n_streams = if streams <= 1 { 2 } else { streams + 1 };
    let slots = n_streams + 1; // + the host timeline at slot 0

    let mut clocks: Vec<Vec<u64>> = vec![vec![0; slots]; slots];
    let mut gate: Option<Vec<u64>> = None;
    let mut next_launch = 0usize;
    let mut alloc_meta: Vec<(String, u32)> = Vec::new(); // (name, pages)
    let mut accs: Vec<Acc> = Vec::new();
    let mut acc_clocks: Vec<Vec<u64>> = Vec::new();

    let host_event = |clocks: &mut Vec<Vec<u64>>| {
        let s0 = clocks[1].clone(); // host verbs block on stream 0
        join(&mut clocks[0], &s0);
        clocks[0][0] += 1;
    };

    for (i, op) in prog.ops.iter().enumerate() {
        match op {
            ReplayOp::MallocManaged { name, size }
            | ReplayOp::MallocDevice { name, size }
            | ReplayOp::MallocHost { name, size } => {
                alloc_meta.push((name.clone(), pages(*size)));
            }
            ReplayOp::HostWrite { alloc, range } | ReplayOp::HostRead { alloc, range } => {
                host_event(&mut clocks);
                let writes = matches!(op, ReplayOp::HostWrite { .. });
                record(
                    &alloc_meta,
                    i,
                    0,
                    alloc.0,
                    *range,
                    writes,
                    &mut accs,
                    &mut acc_clocks,
                    &clocks[0],
                );
            }
            ReplayOp::MemcpyH2D { alloc } | ReplayOp::MemcpyD2H { alloc } => {
                host_event(&mut clocks);
                let writes = matches!(op, ReplayOp::MemcpyH2D { .. });
                if let Some(p) = alloc_meta.get(alloc.0 as usize).map(|(_, p)| *p) {
                    let full = PageRange { start: 0, end: p };
                    record(
                        &alloc_meta,
                        i,
                        0,
                        alloc.0,
                        full,
                        writes,
                        &mut accs,
                        &mut acc_clocks,
                        &clocks[0],
                    );
                }
            }
            ReplayOp::PrefetchBackground { .. } => {
                // Issued from the host, runs on stream 1; its completion
                // gates the next launch. Data movement, not an access.
                let bg = StreamId::BACKGROUND.0 as usize + 1;
                let h = clocks[0].clone();
                join(&mut clocks[bg], &h);
                clocks[bg][bg] += 1;
                gate = Some(clocks[bg].clone());
            }
            ReplayOp::Launch { phases } => {
                let c = next_launch % streams;
                next_launch += 1;
                let sid = if c == 0 { 0 } else { c + 1 }; // default, then created ids 2..
                let slot = sid + 1;
                let h = clocks[0].clone();
                join(&mut clocks[slot], &h);
                if let Some(g) = gate.take() {
                    join(&mut clocks[slot], &g);
                }
                clocks[slot][slot] += 1;
                for ph in phases {
                    for a in &ph.accesses {
                        record(
                            &alloc_meta,
                            i,
                            slot,
                            a.alloc.0,
                            a.range,
                            a.kind.writes(),
                            &mut accs,
                            &mut acc_clocks,
                            &clocks[slot],
                        );
                    }
                }
            }
            ReplayOp::DeviceSync => {
                let joined: Vec<u64> = (0..slots)
                    .map(|k| clocks.iter().map(|c| c[k]).max().unwrap_or(0))
                    .collect();
                clocks[0] = joined;
                clocks[0][0] += 1;
            }
            ReplayOp::Advise { .. } | ReplayOp::PrefetchDefault { .. } => {
                // Metadata / data movement: no data access to race on.
            }
        }
    }

    // Pairwise concurrency check. Program order means a later access
    // can never happen-before an earlier one, so one direction
    // suffices: `a` (earlier) is ordered before `b` iff `b`'s clock
    // has seen `a`'s tick on `a`'s own timeline.
    let mut seen: HashSet<(&'static str, u32, usize, usize)> = HashSet::new();
    for bi in 0..accs.len() {
        for ai in 0..bi {
            let (a, b) = (&accs[ai], &accs[bi]);
            if a.slot == b.slot || a.alloc != b.alloc || !(a.writes || b.writes) {
                continue;
            }
            if a.range.start >= b.range.end || b.range.start >= a.range.end {
                continue;
            }
            if acc_clocks[bi][a.slot] >= acc_clocks[ai][a.slot] {
                continue; // ordered: b happens-after a
            }
            let code = if a.writes && b.writes { RACE_WW } else { RACE_RW };
            let (lo, hi) = (a.slot.min(b.slot), a.slot.max(b.slot));
            if !seen.insert((code, a.alloc, lo, hi)) {
                continue;
            }
            let name = alloc_meta
                .get(a.alloc as usize)
                .map_or_else(|| format!("#{}", a.alloc), |(n, _)| format!("'{n}'"));
            out.push(Diagnostic {
                code,
                severity: Severity::Warning,
                op: Some(b.op),
                message: format!(
                    "{} race on {}: op#{} ({}) pages {}..{} vs op#{} ({}) pages {}..{} — no \
                     synchronization orders them",
                    if code == RACE_WW { "write/write" } else { "write/read" },
                    name,
                    a.op,
                    slot_name(a.slot),
                    a.range.start,
                    a.range.end,
                    b.op,
                    slot_name(b.slot),
                    b.range.start,
                    b.range.end
                ),
            });
        }
    }
}

fn pages(size: Bytes) -> u32 {
    size.div_ceil(crate::mem::PAGE_SIZE) as u32
}

fn join(a: &mut [u64], b: &[u64]) {
    for (x, y) in a.iter_mut().zip(b) {
        *x = (*x).max(*y);
    }
}

/// Record one access if its allocation reference and range are valid
/// (invalid ones are the state pass's findings, not race material).
#[allow(clippy::too_many_arguments)]
fn record(
    alloc_meta: &[(String, u32)],
    op: usize,
    slot: usize,
    alloc: u32,
    range: PageRange,
    writes: bool,
    accs: &mut Vec<Acc>,
    acc_clocks: &mut Vec<Vec<u64>>,
    clock: &[u64],
) {
    let Some((_, pages)) = alloc_meta.get(alloc as usize) else { return };
    if range.start >= range.end || range.end > *pages {
        return;
    }
    accs.push(Acc { op, slot, alloc, range, writes });
    acc_clocks.push(clock.to_vec());
}

fn slot_name(slot: usize) -> String {
    match slot {
        0 => "host".into(),
        1 => "stream 0".into(),
        2 => "background".into(),
        s => format!("stream {}", s - 1),
    }
}

#[cfg(test)]
mod tests {
    use super::super::state::tests::{hr, hw, launch, mm, prog};
    use super::*;
    use crate::gpu::AccessKind;

    fn codes_of(p: &ReplayProgram) -> Vec<&'static str> {
        let mut out = Vec::new();
        check(p, &mut out);
        let mut c: Vec<&'static str> = out.iter().map(|d| d.code).collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    /// Two launches on a 2-stream program land on stream 0 and stream 2.
    fn two_stream(k0: AccessKind, k1: AccessKind, r0: (u32, u32), r1: (u32, u32)) -> ReplayProgram {
        prog(
            2,
            vec![
                mm("a", 128),
                hw(0, 0, 128),
                launch(0, r0.0, r0.1, k0),
                launch(0, r1.0, r1.1, k1),
                ReplayOp::DeviceSync,
                hr(0, 0, 128),
            ],
        )
    }

    #[test]
    fn overlapping_cross_stream_writes_race() {
        let p = two_stream(AccessKind::ReadWrite, AccessKind::Write, (0, 64), (32, 96));
        assert_eq!(codes_of(&p), vec![RACE_WW]);
    }

    #[test]
    fn write_read_overlap_races_and_read_read_does_not() {
        let p = two_stream(AccessKind::Read, AccessKind::Write, (0, 64), (32, 96));
        assert_eq!(codes_of(&p), vec![RACE_RW]);
        let p = two_stream(AccessKind::Read, AccessKind::Read, (0, 64), (32, 96));
        assert!(codes_of(&p).is_empty(), "read/read never races");
    }

    #[test]
    fn disjoint_ranges_do_not_race() {
        let p = two_stream(AccessKind::ReadWrite, AccessKind::ReadWrite, (0, 64), (64, 128));
        assert!(codes_of(&p).is_empty());
    }

    #[test]
    fn device_sync_orders_cross_stream_accesses() {
        let p = prog(
            2,
            vec![
                mm("a", 128),
                hw(0, 0, 128),
                launch(0, 0, 64, AccessKind::ReadWrite),
                ReplayOp::DeviceSync,
                launch(0, 32, 96, AccessKind::ReadWrite),
                ReplayOp::DeviceSync,
                hr(0, 0, 128),
            ],
        );
        assert!(codes_of(&p).is_empty(), "the barrier orders the overlap");
    }

    #[test]
    fn launches_see_prior_host_writes_but_host_reads_race_with_running_kernels() {
        // The setup write is ordered before both kernels (issue edge) —
        // but reading results of a *non-default* stream without a sync
        // is a race, while stream 0 results are ordered (host verbs
        // block on the default stream).
        let racy = prog(
            2,
            vec![
                mm("a", 128),
                hw(0, 0, 128),
                launch(0, 0, 64, AccessKind::Read),       // stream 0
                launch(0, 64, 128, AccessKind::ReadWrite), // stream 2
                hr(0, 64, 128),                            // unsynchronized result read
            ],
        );
        assert_eq!(codes_of(&racy), vec![RACE_RW]);
        let ordered = prog(
            2,
            vec![
                mm("a", 128),
                hw(0, 0, 128),
                launch(0, 0, 64, AccessKind::ReadWrite), // stream 0
                hr(0, 0, 64),                            // blocks on stream 0: ordered
            ],
        );
        assert!(codes_of(&ordered).is_empty());
    }

    #[test]
    fn single_stream_programs_never_race() {
        let p = prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                launch(0, 0, 64, AccessKind::ReadWrite),
                launch(0, 0, 64, AccessKind::ReadWrite),
                hr(0, 0, 64), // blocks on stream 0 — ordered without any sync
            ],
        );
        assert!(codes_of(&p).is_empty());
    }

    #[test]
    fn reports_are_deduplicated_per_pair() {
        // Two racing pairs on the same (alloc, stream pair): one report.
        let p = prog(
            2,
            vec![
                mm("a", 256),
                hw(0, 0, 256),
                launch(0, 0, 64, AccessKind::ReadWrite),
                launch(0, 0, 64, AccessKind::ReadWrite),
                launch(0, 128, 192, AccessKind::ReadWrite),
                launch(0, 128, 192, AccessKind::ReadWrite),
                ReplayOp::DeviceSync,
                hr(0, 0, 256),
            ],
        );
        let mut out = Vec::new();
        check(&p, &mut out);
        assert_eq!(out.len(), 1, "{out:?}");
        assert_eq!(out[0].code, RACE_WW);
    }
}
