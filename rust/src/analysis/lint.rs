//! Pass 3: policy lints (`vet.lint.*`).
//!
//! These are programs that execute fine and race on nothing, but
//! encode a *self-defeating policy* — the semantic smells the paper
//! measures the cost of. Each lint is a straight single-pass scan over
//! the verb stream with a little per-allocation state:
//!
//! * [`super::LINT_READMOSTLY_WRITE`] — a write access (host write,
//!   H2D memcpy, or a writing kernel access) while a `ReadMostly`
//!   advise is active on the allocation. `ReadMostly` replicates pages
//!   to every reader; one write collapses all the duplicates (the
//!   paper's §IV-B worst case).
//! * [`super::LINT_ADVISE_CHURN`] — the same advise family on the same
//!   allocation going set → unset → set. Every transition is a driver
//!   round trip plus a policy re-evaluation; cycling it is a sign the
//!   program is fighting its own hints.
//! * [`super::LINT_PREFETCH_ORDER`] — `PreferredLocation(Gpu)` advised
//!   *after* the allocation was already prefetched to the GPU. The
//!   prefetch ran without the residency hint, so the pages arrived
//!   unpinned and the advise can no longer protect that placement;
//!   advising first is strictly better.
//! * [`super::LINT_STREAMS_UNUSED`] — the header declares more compute
//!   streams than the launch rotation ever reaches: declared
//!   parallelism the program cannot exhibit.
//! * [`super::LINT_UNUSED_ALLOC`] — a managed allocation no later verb
//!   references. Host staging buffers (`MallocHost`) are exempt:
//!   explicit-variant captures legitimately record a staging buffer
//!   whose traffic is represented by memcpy verbs on the device
//!   allocation.

use crate::mem::AllocKind;
use crate::trace::replay::{ReplayOp, ReplayProgram};
use crate::um::{Advise, Loc};

use super::{
    Diagnostic, Severity, LINT_ADVISE_CHURN, LINT_PREFETCH_ORDER, LINT_READMOSTLY_WRITE,
    LINT_STREAMS_UNUSED, LINT_UNUSED_ALLOC,
};

/// Advise families for churn tracking. `AccessedBy(Cpu)` and
/// `AccessedBy(Gpu)` are independent hints, so they churn separately.
const FAMILIES: usize = 4;

fn family(a: Advise) -> Option<(usize, &'static str, bool)> {
    // (family index, display name, is_set)
    match a {
        Advise::ReadMostly => Some((0, "ReadMostly", true)),
        Advise::UnsetReadMostly => Some((0, "ReadMostly", false)),
        Advise::PreferredLocation(_) => Some((1, "PreferredLocation", true)),
        Advise::UnsetPreferredLocation => Some((1, "PreferredLocation", false)),
        Advise::AccessedBy(Loc::Cpu) => Some((2, "AccessedBy(Cpu)", true)),
        Advise::AccessedBy(Loc::Gpu) => Some((3, "AccessedBy(Gpu)", true)),
        Advise::UnsetAccessedBy(Loc::Cpu) => Some((2, "AccessedBy(Cpu)", false)),
        Advise::UnsetAccessedBy(Loc::Gpu) => Some((3, "AccessedBy(Gpu)", false)),
    }
}

/// Per-allocation lint state.
struct St {
    name: String,
    kind: AllocKind,
    malloc_op: usize,
    referenced: bool,
    readmostly: bool,
    readmostly_warned: bool,
    prefetched_gpu: bool,
    prefetch_order_warned: bool,
    /// Per advise family: 0 = never set, 1 = set, 2 = unset after set.
    advise_state: [u8; FAMILIES],
    advise_churn_warned: [bool; FAMILIES],
}

pub(super) fn check(prog: &ReplayProgram, out: &mut Vec<Diagnostic>) {
    let mut sts: Vec<St> = Vec::new();
    let mut launches = 0u64;

    for (i, op) in prog.ops.iter().enumerate() {
        match op {
            ReplayOp::MallocManaged { name, .. }
            | ReplayOp::MallocDevice { name, .. }
            | ReplayOp::MallocHost { name, .. } => {
                let kind = match op {
                    ReplayOp::MallocManaged { .. } => AllocKind::Managed,
                    ReplayOp::MallocDevice { .. } => AllocKind::Device,
                    _ => AllocKind::Host,
                };
                sts.push(St {
                    name: name.clone(),
                    kind,
                    malloc_op: i,
                    referenced: false,
                    readmostly: false,
                    readmostly_warned: false,
                    prefetched_gpu: false,
                    prefetch_order_warned: false,
                    advise_state: [0; FAMILIES],
                    advise_churn_warned: [false; FAMILIES],
                });
            }
            ReplayOp::HostWrite { alloc, .. } => {
                if let Some(st) = sts.get_mut(alloc.0 as usize) {
                    st.referenced = true;
                    warn_readmostly_write(st, i, "host write", out);
                }
            }
            ReplayOp::HostRead { alloc, .. } | ReplayOp::MemcpyD2H { alloc } => {
                if let Some(st) = sts.get_mut(alloc.0 as usize) {
                    st.referenced = true;
                }
            }
            ReplayOp::MemcpyH2D { alloc } => {
                if let Some(st) = sts.get_mut(alloc.0 as usize) {
                    st.referenced = true;
                    warn_readmostly_write(st, i, "H2D memcpy", out);
                }
            }
            ReplayOp::Advise { alloc, advise } => {
                let Some(st) = sts.get_mut(alloc.0 as usize) else { continue };
                st.referenced = true;
                if let Some((f, fname, is_set)) = family(*advise) {
                    if is_set {
                        if st.advise_state[f] == 2 && !st.advise_churn_warned[f] {
                            st.advise_churn_warned[f] = true;
                            out.push(Diagnostic {
                                code: LINT_ADVISE_CHURN,
                                severity: Severity::Warning,
                                op: Some(i),
                                message: format!(
                                    "advise churn on '{}': {fname} set again after a set/unset \
                                     cycle — each transition is a driver round trip",
                                    st.name
                                ),
                            });
                        }
                        st.advise_state[f] = 1;
                    } else if st.advise_state[f] == 1 {
                        st.advise_state[f] = 2;
                    }
                }
                match advise {
                    Advise::ReadMostly => st.readmostly = true,
                    Advise::UnsetReadMostly => st.readmostly = false,
                    Advise::PreferredLocation(Loc::Gpu) => {
                        if st.prefetched_gpu && !st.prefetch_order_warned {
                            st.prefetch_order_warned = true;
                            out.push(Diagnostic {
                                code: LINT_PREFETCH_ORDER,
                                severity: Severity::Warning,
                                op: Some(i),
                                message: format!(
                                    "PreferredLocation(Gpu) advised after '{}' was already \
                                     prefetched to the GPU — the pages arrived unpinned; advise \
                                     before prefetching so the residency hint guides placement",
                                    st.name
                                ),
                            });
                        }
                    }
                    _ => {}
                }
            }
            ReplayOp::PrefetchBackground { alloc, dst }
            | ReplayOp::PrefetchDefault { alloc, dst } => {
                if let Some(st) = sts.get_mut(alloc.0 as usize) {
                    st.referenced = true;
                    if *dst == Loc::Gpu {
                        st.prefetched_gpu = true;
                    }
                }
            }
            ReplayOp::Launch { phases } => {
                launches += 1;
                for ph in phases {
                    for acc in &ph.accesses {
                        if let Some(st) = sts.get_mut(acc.alloc.0 as usize) {
                            st.referenced = true;
                            if acc.kind.writes() {
                                warn_readmostly_write(st, i, "writing kernel access", out);
                            }
                        }
                    }
                }
            }
            ReplayOp::DeviceSync => {}
        }
    }

    let declared = u64::from(prog.streams);
    if declared > 1 && launches < declared {
        out.push(Diagnostic {
            code: LINT_STREAMS_UNUSED,
            severity: Severity::Warning,
            op: None,
            message: format!(
                "header declares {declared} compute streams but only {launches} launch(es) ever \
                 rotate across them — {} stream(s) can never be used",
                declared - launches
            ),
        });
    }

    for st in &sts {
        if st.kind == AllocKind::Managed && !st.referenced {
            out.push(Diagnostic {
                code: LINT_UNUSED_ALLOC,
                severity: Severity::Warning,
                op: Some(st.malloc_op),
                message: format!(
                    "managed allocation '{}' is never referenced by any later verb",
                    st.name
                ),
            });
        }
    }
}

fn warn_readmostly_write(st: &mut St, op: usize, what: &str, out: &mut Vec<Diagnostic>) {
    if st.readmostly && !st.readmostly_warned {
        st.readmostly_warned = true;
        out.push(Diagnostic {
            code: LINT_READMOSTLY_WRITE,
            severity: Severity::Warning,
            op: Some(op),
            message: format!(
                "{what} to '{}' while ReadMostly is active — one write invalidates every \
                 replicated copy; unset the advise before writing",
                st.name
            ),
        });
    }
}

#[cfg(test)]
mod tests {
    use super::super::state::tests::{hw, launch, mm, prog};
    use super::*;
    use crate::gpu::AccessKind;
    use crate::mem::AllocId;

    fn adv(alloc: u32, advise: Advise) -> ReplayOp {
        ReplayOp::Advise { alloc: AllocId(alloc), advise }
    }

    fn codes_of(p: &ReplayProgram) -> Vec<&'static str> {
        let mut out = Vec::new();
        check(p, &mut out);
        let mut c: Vec<&'static str> = out.iter().map(|d| d.code).collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    #[test]
    fn minimal_clean_program_lints_clean() {
        let p = super::super::state::tests::minimal_clean_program();
        assert!(codes_of(&p).is_empty());
    }

    #[test]
    fn write_under_readmostly_warns_once_and_unset_clears() {
        let p = prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                adv(0, Advise::ReadMostly),
                launch(0, 0, 32, AccessKind::ReadWrite),
                launch(0, 32, 64, AccessKind::ReadWrite),
            ],
        );
        let mut out = Vec::new();
        check(&p, &mut out);
        let rm: Vec<_> = out.iter().filter(|d| d.code == LINT_READMOSTLY_WRITE).collect();
        assert_eq!(rm.len(), 1, "deduplicated per allocation: {out:?}");
        assert_eq!(rm[0].op, Some(3), "first writing access after the advise");
        // Unsetting first makes the same write clean.
        let p = prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                adv(0, Advise::ReadMostly),
                launch(0, 0, 32, AccessKind::Read),
                adv(0, Advise::UnsetReadMostly),
                launch(0, 32, 64, AccessKind::ReadWrite),
            ],
        );
        assert!(codes_of(&p).is_empty(), "{:?}", codes_of(&p));
    }

    #[test]
    fn advise_set_unset_set_cycle_is_churn() {
        let p = prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                adv(0, Advise::ReadMostly),
                adv(0, Advise::UnsetReadMostly),
                adv(0, Advise::ReadMostly),
                adv(0, Advise::UnsetReadMostly),
                launch(0, 0, 64, AccessKind::Read),
            ],
        );
        let mut out = Vec::new();
        check(&p, &mut out);
        let churn: Vec<_> = out.iter().filter(|d| d.code == LINT_ADVISE_CHURN).collect();
        assert_eq!(churn.len(), 1, "{out:?}");
        assert_eq!(churn[0].op, Some(4), "the re-set closes the cycle");
        // set → unset alone is not churn; distinct families don't mix.
        let p = prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                adv(0, Advise::ReadMostly),
                adv(0, Advise::UnsetReadMostly),
                adv(0, Advise::PreferredLocation(Loc::Cpu)),
                launch(0, 0, 64, AccessKind::Read),
            ],
        );
        assert!(codes_of(&p).is_empty());
    }

    #[test]
    fn preferred_location_after_gpu_prefetch_is_misordered() {
        let p = prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                ReplayOp::PrefetchBackground { alloc: AllocId(0), dst: Loc::Gpu },
                adv(0, Advise::PreferredLocation(Loc::Gpu)),
                launch(0, 0, 64, AccessKind::Read),
            ],
        );
        assert_eq!(codes_of(&p), vec![LINT_PREFETCH_ORDER]);
        // Advise-then-prefetch (the synth generator's order) is clean.
        let p = prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                adv(0, Advise::PreferredLocation(Loc::Gpu)),
                ReplayOp::PrefetchBackground { alloc: AllocId(0), dst: Loc::Gpu },
                launch(0, 0, 64, AccessKind::Read),
            ],
        );
        assert!(codes_of(&p).is_empty());
    }

    #[test]
    fn declared_streams_the_rotation_never_reaches_warn() {
        let p = prog(
            4,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                launch(0, 0, 32, AccessKind::Read),
                launch(0, 32, 64, AccessKind::Read),
            ],
        );
        let mut out = Vec::new();
        check(&p, &mut out);
        let su: Vec<_> = out.iter().filter(|d| d.code == LINT_STREAMS_UNUSED).collect();
        assert_eq!(su.len(), 1, "{out:?}");
        assert_eq!(su[0].op, None, "whole-program finding");
        // Two launches over two streams reach every stream.
        let p = prog(
            2,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                launch(0, 0, 32, AccessKind::Read),
                launch(0, 32, 64, AccessKind::Read),
            ],
        );
        assert!(codes_of(&p).is_empty());
    }

    #[test]
    fn unreferenced_managed_allocation_warns_but_host_staging_is_exempt() {
        let p = prog(
            1,
            vec![
                mm("used", 64),
                mm("orphan", 64),
                hw(0, 0, 64),
                launch(0, 0, 64, AccessKind::Read),
            ],
        );
        let mut out = Vec::new();
        check(&p, &mut out);
        let ua: Vec<_> = out.iter().filter(|d| d.code == LINT_UNUSED_ALLOC).collect();
        assert_eq!(ua.len(), 1, "{out:?}");
        assert_eq!(ua[0].op, Some(1));
        assert!(ua[0].message.contains("orphan"), "{}", ua[0].message);
        // The explicit variant's staging buffer shape: a MallocHost the
        // memcpy verbs never name directly.
        let p = prog(
            1,
            vec![
                ReplayOp::MallocDevice { name: "d".into(), size: 64 * crate::mem::PAGE_SIZE },
                ReplayOp::MallocHost { name: "h".into(), size: 64 * crate::mem::PAGE_SIZE },
                ReplayOp::MemcpyH2D { alloc: AllocId(0) },
                launch(0, 0, 64, AccessKind::Read),
            ],
        );
        assert!(codes_of(&p).is_empty());
    }
}
