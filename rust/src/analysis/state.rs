//! Pass 1: flow-sensitive abstract interpretation over the
//! allocation-state lattice (`vet.alloc.*`).
//!
//! The abstract state is deliberately tiny: the verb language has no
//! free verb, so an allocation id moves through exactly two lattice
//! points — *unallocated* (no malloc has produced it yet) and
//! *allocated* with a known `(kind, pages, bytes)`. Walking the verb
//! stream once against that state decides, exactly:
//!
//! * every reference resolves ([`super::ALLOC_UNALLOCATED`]) — ids are
//!   assigned in malloc order, so "allocated later in the program" is
//!   still a use-before-allocation at this verb;
//! * every page range fits its allocation ([`super::ALLOC_OOB`]);
//! * every verb is meaningful for the allocation's kind
//!   ([`super::ALLOC_KIND`]): host accesses to `cudaMalloc` memory
//!   panic in the executor, advises/prefetches of non-managed memory
//!   are CUDA errors (the runtime degrades them to no-ops), and
//!   memcpys must name the device-side allocation;
//! * launches touch at least one page ([`super::ALLOC_EMPTY_LAUNCH`]);
//! * the distinct prefetch-to-GPU footprint fits usable device memory
//!   ([`super::ALLOC_OVERCOMMIT`]) — a prefetch set larger than the
//!   device guarantees eviction thrash, which is either an
//!   oversubscription regime the program should enter *without*
//!   bulk-prefetching, or a generator bug;
//! * no hint verb is dead ([`super::ALLOC_DEAD_VERB`]): an advise or a
//!   GPU-directed prefetch after the final launch can never be
//!   observed by a kernel.

use crate::mem::{AllocKind, PageRange};
use crate::trace::replay::{ReplayOp, ReplayProgram};
use crate::um::Loc;
use crate::util::units::{fmt_bytes, Bytes};

use super::{
    Diagnostic, Severity, ALLOC_DEAD_VERB, ALLOC_EMPTY_LAUNCH, ALLOC_KIND, ALLOC_OOB,
    ALLOC_OVERCOMMIT, ALLOC_UNALLOCATED,
};

/// Abstract state of one allocation: everything later verbs can be
/// checked against.
struct AllocSt {
    name: String,
    kind: AllocKind,
    pages: u32,
    bytes: Bytes,
}

pub(super) fn check(prog: &ReplayProgram, out: &mut Vec<Diagnostic>) {
    let spec = prog.platform.spec();
    let usable = spec.gpu.usable();
    let last_launch = prog.ops.iter().rposition(|o| matches!(o, ReplayOp::Launch { .. }));
    let mut allocs: Vec<AllocSt> = Vec::new();
    // Distinct allocations already counted toward the prefetch-to-GPU
    // footprint (re-prefetching the same allocation is not overcommit).
    let mut prefetched_gpu: Vec<bool> = Vec::new();
    let mut prefetch_footprint: Bytes = 0;
    let mut overcommit_reported = false;

    for (i, op) in prog.ops.iter().enumerate() {
        // A hint verb is dead once no launch can follow it. (A
        // CPU-directed prefetch after the last launch is legitimate
        // result staging and stays exempt.)
        let dead = |out: &mut Vec<Diagnostic>, what: &str| {
            out.push(Diagnostic {
                code: ALLOC_DEAD_VERB,
                severity: Severity::Warning,
                op: Some(i),
                message: format!("{what} after the final kernel launch — no kernel can observe it"),
            });
        };
        match op {
            ReplayOp::MallocManaged { name, size } => {
                allocs.push(alloc_st(name, AllocKind::Managed, *size));
                prefetched_gpu.push(false);
            }
            ReplayOp::MallocDevice { name, size } => {
                allocs.push(alloc_st(name, AllocKind::Device, *size));
                prefetched_gpu.push(false);
            }
            ReplayOp::MallocHost { name, size } => {
                allocs.push(alloc_st(name, AllocKind::Host, *size));
                prefetched_gpu.push(false);
            }
            ReplayOp::HostWrite { alloc, range } | ReplayOp::HostRead { alloc, range } => {
                let verb = if matches!(op, ReplayOp::HostWrite { .. }) {
                    "host write"
                } else {
                    "host read"
                };
                let Some(a) = resolve(&allocs, i, alloc.0, verb, out) else { continue };
                if a.kind == AllocKind::Device {
                    out.push(Diagnostic {
                        code: ALLOC_KIND,
                        severity: Severity::Error,
                        op: Some(i),
                        message: format!(
                            "{verb} to cudaMalloc allocation '{}' — the executor panics on host \
                             access to device memory; use a memcpy verb",
                            a.name
                        ),
                    });
                    continue;
                }
                check_range(a, i, verb, *range, out);
            }
            ReplayOp::Advise { alloc, .. } => {
                let Some(a) = resolve(&allocs, i, alloc.0, "advise", out) else { continue };
                if a.kind != AllocKind::Managed {
                    out.push(Diagnostic {
                        code: ALLOC_KIND,
                        severity: Severity::Error,
                        op: Some(i),
                        message: format!(
                            "advise on non-managed allocation '{}' — cudaMemAdvise requires \
                             managed memory",
                            a.name
                        ),
                    });
                } else if last_launch.is_none_or(|l| i > l) {
                    dead(out, "advise");
                }
            }
            ReplayOp::PrefetchBackground { alloc, dst }
            | ReplayOp::PrefetchDefault { alloc, dst } => {
                let Some(a) = resolve(&allocs, i, alloc.0, "prefetch", out) else { continue };
                if a.kind != AllocKind::Managed {
                    out.push(Diagnostic {
                        code: ALLOC_KIND,
                        severity: Severity::Error,
                        op: Some(i),
                        message: format!(
                            "prefetch of non-managed allocation '{}' — cudaMemPrefetchAsync \
                             requires managed memory (the runtime degrades this to a no-op)",
                            a.name
                        ),
                    });
                    continue;
                }
                if *dst == Loc::Gpu {
                    if last_launch.is_none_or(|l| i > l) {
                        dead(out, "prefetch to GPU");
                    }
                    let idx = alloc.0 as usize;
                    if !prefetched_gpu[idx] {
                        prefetched_gpu[idx] = true;
                        prefetch_footprint += a.bytes;
                        if !overcommit_reported && prefetch_footprint > usable {
                            overcommit_reported = true;
                            // On a coherent (Grace-class) platform the
                            // advice changes: the eviction churn also
                            // throws away counter-placed pages, and
                            // host-resident data is already serviced
                            // fault-free over C2C (docs/PLATFORMS.md) —
                            // so the fix is to drop the prefetch, not
                            // shrink it.
                            let message = if spec.um.coherent {
                                format!(
                                    "cumulative prefetch-to-GPU footprint {} exceeds usable \
                                     device memory {} on coherent {} — eviction churn will \
                                     discard counter-placed pages; leave the cold set \
                                     host-resident and let the access counters migrate the \
                                     hot subset",
                                    fmt_bytes(prefetch_footprint),
                                    fmt_bytes(usable),
                                    prog.platform.name()
                                )
                            } else {
                                format!(
                                    "cumulative prefetch-to-GPU footprint {} exceeds usable \
                                     device memory {} on {} — the prefetched set cannot \
                                     co-reside and will thrash eviction",
                                    fmt_bytes(prefetch_footprint),
                                    fmt_bytes(usable),
                                    prog.platform.name()
                                )
                            };
                            out.push(Diagnostic {
                                code: ALLOC_OVERCOMMIT,
                                severity: Severity::Warning,
                                op: Some(i),
                                message,
                            });
                        }
                    }
                }
            }
            ReplayOp::MemcpyH2D { alloc } | ReplayOp::MemcpyD2H { alloc } => {
                let Some(a) = resolve(&allocs, i, alloc.0, "memcpy", out) else { continue };
                if a.kind == AllocKind::Host {
                    out.push(Diagnostic {
                        code: ALLOC_KIND,
                        severity: Severity::Error,
                        op: Some(i),
                        message: format!(
                            "memcpy names host staging allocation '{}' — name the device-side \
                             allocation being copied",
                            a.name
                        ),
                    });
                }
            }
            ReplayOp::Launch { phases } => {
                let mut touched = 0u64;
                for ph in phases {
                    for acc in &ph.accesses {
                        let Some(a) = resolve(&allocs, i, acc.alloc.0, "kernel access", out)
                        else {
                            continue;
                        };
                        check_range(a, i, "kernel access", acc.range, out);
                        touched += u64::from(acc.range.end.saturating_sub(acc.range.start));
                    }
                }
                if touched == 0 {
                    out.push(Diagnostic {
                        code: ALLOC_EMPTY_LAUNCH,
                        severity: Severity::Warning,
                        op: Some(i),
                        message: "kernel launch with an empty access set — no pages touched, \
                                  nothing to measure"
                            .into(),
                    });
                }
            }
            ReplayOp::DeviceSync => {}
        }
    }
}

fn alloc_st(name: &str, kind: AllocKind, size: Bytes) -> AllocSt {
    AllocSt {
        name: name.to_string(),
        kind,
        pages: size.div_ceil(crate::mem::PAGE_SIZE) as u32,
        bytes: size,
    }
}

/// Resolve an allocation reference against the abstract state; emits
/// [`ALLOC_UNALLOCATED`] and yields `None` when the id has not been
/// produced by any malloc verb yet.
fn resolve<'a>(
    allocs: &'a [AllocSt],
    op: usize,
    id: u32,
    verb: &str,
    out: &mut Vec<Diagnostic>,
) -> Option<&'a AllocSt> {
    let a = allocs.get(id as usize);
    if a.is_none() {
        out.push(Diagnostic {
            code: ALLOC_UNALLOCATED,
            severity: Severity::Error,
            op: Some(op),
            message: format!(
                "{verb} references allocation #{id}, but only {} allocation(s) exist at this \
                 point in the program",
                allocs.len()
            ),
        });
    }
    a
}

/// Bounds-check a page range against its allocation; inverted ranges
/// count as out of bounds too (they cannot come from `PageRange::new`,
/// only from a corrupted capture).
fn check_range(a: &AllocSt, op: usize, verb: &str, range: PageRange, out: &mut Vec<Diagnostic>) {
    if range.start > range.end || range.end > a.pages {
        out.push(Diagnostic {
            code: ALLOC_OOB,
            severity: Severity::Error,
            op: Some(op),
            message: format!(
                "{verb} window {}..{} exceeds allocation '{}' ({} pages)",
                range.start, range.end, a.name, a.pages
            ),
        });
    }
}

#[cfg(test)]
pub(crate) mod tests {
    use super::*;
    use crate::apps::Variant;
    use crate::gpu::AccessKind;
    use crate::mem::{AllocId, PAGE_SIZE};
    use crate::platform::PlatformId;
    use crate::sim::InjectConfig;
    use crate::trace::replay::{ReplayAccess, ReplayPhase};
    use crate::um::{Advise, EvictorKind, PredictorKind};

    pub(crate) fn prog(streams: u32, ops: Vec<ReplayOp>) -> ReplayProgram {
        ReplayProgram {
            app: "test".into(),
            platform: PlatformId::IntelPascal,
            variant: Variant::UmAuto,
            streams,
            predictor: PredictorKind::default(),
            evictor: EvictorKind::default(),
            inject: InjectConfig::default(),
            ops,
        }
    }

    pub(crate) fn mm(name: &str, pages: u32) -> ReplayOp {
        ReplayOp::MallocManaged { name: name.into(), size: u64::from(pages) * PAGE_SIZE }
    }

    pub(crate) fn launch(alloc: u32, start: u32, end: u32, kind: AccessKind) -> ReplayOp {
        ReplayOp::Launch {
            phases: vec![ReplayPhase {
                flops_bits: 1.0f64.to_bits(),
                accesses: vec![ReplayAccess {
                    alloc: AllocId(alloc),
                    range: PageRange { start, end },
                    kind,
                    passes_bits: 1.0f64.to_bits(),
                }],
            }],
        }
    }

    pub(crate) fn hw(alloc: u32, start: u32, end: u32) -> ReplayOp {
        ReplayOp::HostWrite { alloc: AllocId(alloc), range: PageRange { start, end } }
    }

    pub(crate) fn hr(alloc: u32, start: u32, end: u32) -> ReplayOp {
        ReplayOp::HostRead { alloc: AllocId(alloc), range: PageRange { start, end } }
    }

    /// A small single-stream program every pass accepts.
    pub(crate) fn minimal_clean_program() -> ReplayProgram {
        prog(
            1,
            vec![
                mm("a", 64),
                hw(0, 0, 64),
                launch(0, 0, 32, AccessKind::Read),
                launch(0, 32, 64, AccessKind::ReadWrite),
                ReplayOp::DeviceSync,
                hr(0, 0, 64),
            ],
        )
    }

    fn codes_of(p: &ReplayProgram) -> Vec<&'static str> {
        let mut out = Vec::new();
        check(p, &mut out);
        let mut c: Vec<&'static str> = out.iter().map(|d| d.code).collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    #[test]
    fn clean_program_passes() {
        assert!(codes_of(&minimal_clean_program()).is_empty());
    }

    #[test]
    fn unallocated_reference_is_an_error() {
        let p = prog(1, vec![mm("a", 64), hw(3, 0, 8)]);
        assert_eq!(codes_of(&p), vec![ALLOC_UNALLOCATED]);
        // Allocated *later* is still unallocated at the point of use.
        let p = prog(1, vec![hw(0, 0, 8), mm("a", 64)]);
        assert_eq!(codes_of(&p), vec![ALLOC_UNALLOCATED]);
    }

    #[test]
    fn out_of_bounds_and_inverted_windows_are_errors() {
        let p = prog(1, vec![mm("a", 64), hw(0, 0, 65)]);
        assert_eq!(codes_of(&p), vec![ALLOC_OOB]);
        let p = prog(1, vec![mm("a", 64), launch(0, 48, 12, AccessKind::Read)]);
        assert_eq!(codes_of(&p), vec![ALLOC_OOB]);
    }

    #[test]
    fn host_access_to_device_memory_is_a_kind_error() {
        let p = prog(
            1,
            vec![ReplayOp::MallocDevice { name: "d".into(), size: 4 * PAGE_SIZE }, hw(0, 0, 4)],
        );
        assert_eq!(codes_of(&p), vec![ALLOC_KIND]);
    }

    #[test]
    fn advise_and_prefetch_require_managed_memory() {
        let dev = ReplayOp::MallocDevice { name: "d".into(), size: 4 * PAGE_SIZE };
        let p = prog(
            1,
            vec![
                dev.clone(),
                ReplayOp::Advise { alloc: AllocId(0), advise: Advise::ReadMostly },
                launch(0, 0, 4, AccessKind::Read),
            ],
        );
        assert_eq!(codes_of(&p), vec![ALLOC_KIND]);
        let p = prog(
            1,
            vec![
                dev,
                ReplayOp::PrefetchBackground { alloc: AllocId(0), dst: Loc::Gpu },
                launch(0, 0, 4, AccessKind::Read),
            ],
        );
        assert_eq!(codes_of(&p), vec![ALLOC_KIND]);
    }

    #[test]
    fn empty_launch_is_a_warning() {
        let p = prog(1, vec![mm("a", 64), ReplayOp::Launch { phases: vec![] }, hw(0, 0, 1)]);
        assert_eq!(codes_of(&p), vec![ALLOC_EMPTY_LAUNCH]);
    }

    #[test]
    fn prefetch_overcommit_is_flagged_once_and_deduped() {
        // Two allocations of 40960 pages = 2.5 GiB each on a 4 GiB
        // device: the second prefetch crosses usable capacity; the
        // repeat prefetch of alloc 0 never re-counts.
        let pf = |a| ReplayOp::PrefetchBackground { alloc: AllocId(a), dst: Loc::Gpu };
        let p = prog(
            1,
            vec![
                mm("x", 40960),
                mm("y", 40960),
                pf(0),
                pf(0),
                pf(1),
                launch(0, 0, 64, AccessKind::Read),
            ],
        );
        let mut out = Vec::new();
        check(&p, &mut out);
        let over: Vec<_> = out.iter().filter(|d| d.code == ALLOC_OVERCOMMIT).collect();
        assert_eq!(over.len(), 1, "{out:?}");
        assert_eq!(over[0].op, Some(4), "reported at the crossing prefetch");
        // A single 2.5 GiB prefetch set stays under usable capacity.
        let p = prog(1, vec![mm("x", 40960), pf(0), launch(0, 0, 64, AccessKind::Read)]);
        assert!(codes_of(&p).is_empty());
    }

    #[test]
    fn hints_after_the_final_launch_are_dead() {
        let p = prog(
            1,
            vec![
                mm("a", 64),
                launch(0, 0, 64, AccessKind::Read),
                ReplayOp::DeviceSync,
                ReplayOp::Advise { alloc: AllocId(0), advise: Advise::ReadMostly },
                ReplayOp::PrefetchBackground { alloc: AllocId(0), dst: Loc::Gpu },
            ],
        );
        let mut out = Vec::new();
        check(&p, &mut out);
        assert_eq!(out.iter().filter(|d| d.code == ALLOC_DEAD_VERB).count(), 2);
        // A CPU-directed prefetch after the last launch is result
        // staging, not a dead verb.
        let p = prog(
            1,
            vec![
                mm("a", 64),
                launch(0, 0, 64, AccessKind::Read),
                ReplayOp::DeviceSync,
                ReplayOp::PrefetchDefault { alloc: AllocId(0), dst: Loc::Cpu },
            ],
        );
        assert!(codes_of(&p).is_empty());
    }
}
