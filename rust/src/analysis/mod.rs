//! Static verification of replay programs (`umbra vet`).
//!
//! A [`crate::trace::replay::ReplayProgram`] is a program in a
//! 12-opcode verb language, and like any program it can be *wrong*
//! before it is ever slow: verbs referencing allocations that don't
//! exist, windows past an allocation's end, hints that contradict the
//! accesses they are supposed to help (the paper's §IV-B ReadMostly
//! misapplication), or cross-stream accesses with no synchronization
//! between them. All of these are decidable from the verb stream
//! alone — no simulated nanosecond needs to run — so this module
//! checks them statically, before `umbra replay` spends cycles and
//! before a corrupted or hand-edited corpus file fails deep inside the
//! simulator with an unactionable panic.
//!
//! Three passes, one family of diagnostic codes each (docs/ANALYSIS.md
//! has the full table with worked examples):
//!
//! * [`state`] — a flow-sensitive abstract interpreter over the
//!   allocation-state lattice (`vet.alloc.*`): existence, kind and
//!   bounds of every verb's allocation reference, empty launches,
//!   device-capacity overcommit by prefetch, dead hint verbs after the
//!   final launch.
//! * [`race`] — a happens-before race detector (`vet.race.*`): vector
//!   clocks over the per-stream verb timelines, with the executor's
//!   exact ordering edges (host verbs block on the default stream,
//!   launches see all host work issued before them, background
//!   prefetches gate the next launch, `DeviceSync` is a global
//!   barrier). Cross-stream overlapping accesses with at least one
//!   write and no ordering path between them are reported.
//! * [`lint`] — policy lints (`vet.lint.*`): semantic smells the paper
//!   warns about — writes under an active `ReadMostly`, advise
//!   set/unset churn, prefetch-before-advise orderings that defeat
//!   escalation, and header/verb mismatches.
//!
//! Every diagnostic carries a stable machine-readable code, a severity
//! and (where meaningful) the offending op index. Severity policy:
//! *errors* are programs the executor cannot run faithfully (replay
//! refuses them without `--no-vet`); *warnings* are programs that run
//! but encode a hazard or a self-defeating policy (CI's `--deny
//! warnings` treats them as fatal for committed corpora).

pub mod lint;
pub mod race;
pub mod state;

use crate::trace::replay::ReplayProgram;
use crate::util::jsonout::Json;

// --- stable diagnostic codes -----------------------------------------
// Append-only: external tooling (CI annotations, the committed vet
// artifact) keys on these strings.

/// Verb references an allocation id no malloc has produced yet.
pub const ALLOC_UNALLOCATED: &str = "vet.alloc.unallocated";
/// Page range extends past the allocation's end (or is inverted).
pub const ALLOC_OOB: &str = "vet.alloc.oob";
/// Verb is meaningless or fatal for the allocation's kind (e.g. a host
/// access to `cudaMalloc` memory — the executor panics on it).
pub const ALLOC_KIND: &str = "vet.alloc.kind";
/// Kernel launch whose phases touch no pages at all.
pub const ALLOC_EMPTY_LAUNCH: &str = "vet.alloc.empty-launch";
/// Cumulative distinct prefetch-to-GPU footprint exceeds usable device
/// memory — the prefetched set cannot co-reside and will thrash.
pub const ALLOC_OVERCOMMIT: &str = "vet.alloc.overcommit";
/// Advise / GPU-directed prefetch after the final launch: no kernel can
/// ever observe its effect.
pub const ALLOC_DEAD_VERB: &str = "vet.alloc.dead-verb";

/// Unordered cross-stream write/write overlap.
pub const RACE_WW: &str = "vet.race.ww";
/// Unordered cross-stream write/read overlap.
pub const RACE_RW: &str = "vet.race.rw";

/// Write access while a `ReadMostly` advise is active on the
/// allocation (invalidates every duplicate; paper §IV-B).
pub const LINT_READMOSTLY_WRITE: &str = "vet.lint.readmostly-write";
/// Set → unset → set cycle of the same advise family on one
/// allocation (each transition is a full driver round trip).
pub const LINT_ADVISE_CHURN: &str = "vet.lint.advise-churn";
/// `PreferredLocation(Gpu)` advise issued *after* a prefetch to GPU of
/// the same allocation — the prefetch ran unpinned, so the advise can
/// no longer protect it from eviction-then-refault.
pub const LINT_PREFETCH_ORDER: &str = "vet.lint.prefetch-order";
/// Header declares more compute streams than the launches ever rotate
/// across.
pub const LINT_STREAMS_UNUSED: &str = "vet.lint.streams-unused";
/// Managed allocation no later verb ever references.
pub const LINT_UNUSED_ALLOC: &str = "vet.lint.unused-alloc";

/// The full code registry: `(code, severity)` for every diagnostic the
/// three passes can emit. Tests assert emitted codes stay registered.
pub const CODES: [(&str, Severity); 13] = [
    (ALLOC_UNALLOCATED, Severity::Error),
    (ALLOC_OOB, Severity::Error),
    (ALLOC_KIND, Severity::Error),
    (ALLOC_EMPTY_LAUNCH, Severity::Warning),
    (ALLOC_OVERCOMMIT, Severity::Warning),
    (ALLOC_DEAD_VERB, Severity::Warning),
    (RACE_WW, Severity::Warning),
    (RACE_RW, Severity::Warning),
    (LINT_READMOSTLY_WRITE, Severity::Warning),
    (LINT_ADVISE_CHURN, Severity::Warning),
    (LINT_PREFETCH_ORDER, Severity::Warning),
    (LINT_STREAMS_UNUSED, Severity::Warning),
    (LINT_UNUSED_ALLOC, Severity::Warning),
];

/// Diagnostic severity. `Error` means the executor cannot run the
/// program faithfully (replay refuses without `--no-vet`); `Warning`
/// means it runs but encodes a hazard (`--deny warnings` makes these
/// fatal too).
#[derive(Clone, Copy, Debug, PartialEq, Eq, PartialOrd, Ord)]
pub enum Severity {
    Warning,
    Error,
}

impl Severity {
    pub fn name(self) -> &'static str {
        match self {
            Severity::Warning => "warning",
            Severity::Error => "error",
        }
    }
}

/// One finding: a stable code, its severity, the offending op index
/// (`None` for whole-program findings like a header mismatch) and a
/// human-readable message.
#[derive(Clone, Debug, PartialEq)]
pub struct Diagnostic {
    pub code: &'static str,
    pub severity: Severity,
    /// Index into `prog.ops` (`None` for header/whole-program findings).
    pub op: Option<usize>,
    pub message: String,
}

impl Diagnostic {
    /// One-line rendering: `error[vet.alloc.oob] op#12: ...`.
    pub fn render(&self) -> String {
        match self.op {
            Some(i) => format!("{}[{}] op#{i}: {}", self.severity.name(), self.code, self.message),
            None => format!("{}[{}]: {}", self.severity.name(), self.code, self.message),
        }
    }
}

/// The result of vetting one program: every diagnostic, ordered by op
/// index (whole-program findings last) then code — deterministic for a
/// given program byte-for-byte.
#[derive(Clone, Debug, Default, PartialEq)]
pub struct VetReport {
    pub diagnostics: Vec<Diagnostic>,
}

impl VetReport {
    pub fn errors(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Error).count()
    }

    pub fn warnings(&self) -> usize {
        self.diagnostics.iter().filter(|d| d.severity == Severity::Warning).count()
    }

    pub fn is_clean(&self) -> bool {
        self.diagnostics.is_empty()
    }

    /// The distinct codes present, sorted (mutation tests key on this).
    pub fn codes(&self) -> Vec<&'static str> {
        let mut c: Vec<&'static str> = self.diagnostics.iter().map(|d| d.code).collect();
        c.sort_unstable();
        c.dedup();
        c
    }

    /// JSON form for `json/vet.json` (one object per vetted file).
    pub fn to_json(&self) -> Json {
        Json::obj(vec![
            ("errors", Json::Int(self.errors() as u64)),
            ("warnings", Json::Int(self.warnings() as u64)),
            (
                "diagnostics",
                Json::Arr(
                    self.diagnostics
                        .iter()
                        .map(|d| {
                            Json::obj(vec![
                                ("code", Json::str(d.code)),
                                ("severity", Json::str(d.severity.name())),
                                ("op", d.op.map_or(Json::Null, |i| Json::Int(i as u64))),
                                ("message", Json::str(d.message.as_str())),
                            ])
                        })
                        .collect(),
                ),
            ),
        ])
    }
}

/// Vet a program: run all three passes and return every finding. Pure
/// and deterministic — same program bytes, same report, no timing is
/// ever executed.
pub fn vet(prog: &ReplayProgram) -> VetReport {
    let mut diagnostics = Vec::new();
    state::check(prog, &mut diagnostics);
    race::check(prog, &mut diagnostics);
    lint::check(prog, &mut diagnostics);
    diagnostics.sort_by_key(|d| (d.op.unwrap_or(usize::MAX), d.code));
    VetReport { diagnostics }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::mem::{AllocId, PageRange};
    use crate::trace::replay::ReplayOp;

    #[test]
    fn registry_is_unique_and_well_formed() {
        let mut codes: Vec<&str> = CODES.iter().map(|(c, _)| *c).collect();
        codes.sort_unstable();
        let n = codes.len();
        codes.dedup();
        assert_eq!(codes.len(), n, "codes are unique");
        for (code, _) in CODES {
            let fam = code.split('.').collect::<Vec<_>>();
            assert_eq!(fam.len(), 3, "{code}: vet.<family>.<name>");
            assert_eq!(fam[0], "vet");
            assert!(matches!(fam[1], "alloc" | "race" | "lint"), "{code}");
        }
    }

    #[test]
    fn clean_program_vets_clean_and_report_is_deterministic() {
        let p = crate::analysis::state::tests::minimal_clean_program();
        let a = vet(&p);
        assert!(a.is_clean(), "{:?}", a.diagnostics);
        assert_eq!(a, vet(&p), "deterministic");
    }

    #[test]
    fn emitted_codes_are_registered_with_matching_severity() {
        // A deliberately broken program exercising several passes.
        let mut p = crate::analysis::state::tests::minimal_clean_program();
        p.ops.push(ReplayOp::HostRead {
            alloc: AllocId(77),
            range: PageRange { start: 0, end: 1 },
        });
        let report = vet(&p);
        assert!(!report.is_clean());
        for d in &report.diagnostics {
            let (_, sev) = CODES
                .iter()
                .find(|(c, _)| *c == d.code)
                .unwrap_or_else(|| panic!("{}: unregistered code", d.code));
            assert_eq!(*sev, d.severity, "{}", d.code);
        }
    }

    #[test]
    fn render_and_json_carry_the_code() {
        let d = Diagnostic {
            code: ALLOC_OOB,
            severity: Severity::Error,
            op: Some(3),
            message: "window 0..99 exceeds 'a' (64 pages)".into(),
        };
        assert_eq!(d.render(), "error[vet.alloc.oob] op#3: window 0..99 exceeds 'a' (64 pages)");
        let r = VetReport { diagnostics: vec![d] };
        assert_eq!(r.errors(), 1);
        assert_eq!(r.warnings(), 0);
        let j = r.to_json().render();
        assert!(j.contains("vet.alloc.oob"), "{j}");
        assert!(j.contains("\"op\": 3"), "{j}");
    }
}
