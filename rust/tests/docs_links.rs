//! Documentation link check (run by the CI docs job): every relative
//! markdown link in `README.md` and `docs/*.md` must point at an
//! existing file, and every `#anchor` must match a heading in the
//! target document (GitHub slugification: lowercase, punctuation
//! stripped, spaces to hyphens).

use std::fs;
use std::path::{Path, PathBuf};

fn repo_root() -> PathBuf {
    // CARGO_MANIFEST_DIR is <repo>/rust.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root").to_path_buf()
}

/// The documents under check: README.md plus everything in docs/.
fn doc_files() -> Vec<PathBuf> {
    let root = repo_root();
    let mut files = vec![root.join("README.md")];
    let docs = root.join("docs");
    if docs.is_dir() {
        let mut entries: Vec<PathBuf> = fs::read_dir(&docs)
            .expect("read docs/")
            .filter_map(|e| e.ok().map(|e| e.path()))
            .filter(|p| p.extension().is_some_and(|x| x == "md"))
            .collect();
        entries.sort();
        files.extend(entries);
    }
    files
}

/// Extract `](target)` link targets. Fenced code blocks are skipped;
/// inline code spans are NOT — don't quote literal markdown link
/// syntax in backticks in the checked documents.
fn link_targets(markdown: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in markdown.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if in_fence {
            continue;
        }
        let mut rest = line;
        while let Some(i) = rest.find("](") {
            let tail = &rest[i + 2..];
            let Some(end) = tail.find(')') else { break };
            out.push(tail[..end].trim().to_string());
            rest = &tail[end + 1..];
        }
    }
    out
}

/// GitHub-style heading slug: lowercase; keep alphanumerics, hyphens,
/// underscores; spaces become hyphens; everything else is dropped.
fn slugify(heading: &str) -> String {
    let mut slug = String::new();
    for c in heading.trim().chars() {
        match c {
            c if c.is_alphanumeric() => slug.extend(c.to_lowercase()),
            ' ' => slug.push('-'),
            '-' | '_' => slug.push(c),
            _ => {}
        }
    }
    slug
}

/// All heading anchors of a markdown document.
fn anchors(markdown: &str) -> Vec<String> {
    let mut out = Vec::new();
    let mut in_fence = false;
    for line in markdown.lines() {
        if line.trim_start().starts_with("```") {
            in_fence = !in_fence;
            continue;
        }
        if !in_fence && line.starts_with('#') {
            out.push(slugify(line.trim_start_matches('#')));
        }
    }
    out
}

#[test]
fn relative_links_and_anchors_resolve() {
    let mut errors = Vec::new();
    for file in doc_files() {
        let text = fs::read_to_string(&file)
            .unwrap_or_else(|e| panic!("read {}: {e}", file.display()));
        let dir = file.parent().expect("doc has a parent dir");
        for target in link_targets(&text) {
            if target.starts_with("http://")
                || target.starts_with("https://")
                || target.starts_with("mailto:")
                || target.is_empty()
            {
                continue;
            }
            let (path_part, anchor) = match target.split_once('#') {
                Some((p, a)) => (p, Some(a.to_string())),
                None => (target.as_str(), None),
            };
            // Resolve the file part (empty = same document).
            let resolved =
                if path_part.is_empty() { file.clone() } else { dir.join(path_part) };
            if !resolved.exists() {
                errors.push(format!(
                    "{}: broken link '{target}' ({} does not exist)",
                    file.display(),
                    resolved.display()
                ));
                continue;
            }
            if let Some(anchor) = anchor {
                if resolved.extension().is_some_and(|x| x == "md") {
                    let target_text = fs::read_to_string(&resolved)
                        .unwrap_or_else(|e| panic!("read {}: {e}", resolved.display()));
                    if !anchors(&target_text).contains(&anchor) {
                        errors.push(format!(
                            "{}: anchor '#{anchor}' not found in {}",
                            file.display(),
                            resolved.display()
                        ));
                    }
                }
            }
        }
    }
    assert!(errors.is_empty(), "documentation link check failed:\n{}", errors.join("\n"));
}

#[test]
fn required_documents_exist_and_are_linked() {
    let root = repo_root();
    for doc in [
        "docs/ARCHITECTURE.md",
        "docs/PLATFORMS.md",
        "docs/PREDICTOR.md",
        "docs/EVICTION.md",
        "docs/ROBUSTNESS.md",
        "docs/OBSERVABILITY.md",
        "docs/REPLAY.md",
        "docs/ANALYSIS.md",
    ] {
        assert!(root.join(doc).exists(), "{doc} missing");
    }
    let readme = fs::read_to_string(root.join("README.md")).unwrap();
    assert!(
        readme.contains("docs/ARCHITECTURE.md")
            && readme.contains("docs/PLATFORMS.md")
            && readme.contains("docs/PREDICTOR.md")
            && readme.contains("docs/EVICTION.md")
            && readme.contains("docs/ROBUSTNESS.md")
            && readme.contains("docs/OBSERVABILITY.md")
            && readme.contains("docs/REPLAY.md")
            && readme.contains("docs/ANALYSIS.md"),
        "README must link the architecture, platforms, predictor, eviction, robustness, \
         observability, replay and analysis docs"
    );
    // The eviction doc's headline sections are link targets from the
    // README and ARCHITECTURE: pin their anchors.
    let eviction = fs::read_to_string(root.join("docs/EVICTION.md")).unwrap();
    let required = [
        "the-dead-range-ranker",
        "when-learned-eviction-loses",
        "the-hint-seam---evictor-learned",
    ];
    for anchor in required {
        assert!(
            anchors(&eviction).iter().any(|a| a == anchor || a.starts_with(anchor)),
            "docs/EVICTION.md lost the '{anchor}' section"
        );
    }
    // Same for the robustness doc: the chaos-layer/watchdog sections
    // are referenced from the README, ARCHITECTURE and rustdoc.
    let robustness = fs::read_to_string(root.join("docs/ROBUSTNESS.md")).unwrap();
    let required = ["the-chaos-layer", "the-watchdog-ladder", "bounded-retry-and-backoff"];
    for anchor in required {
        assert!(
            anchors(&robustness).iter().any(|a| a == anchor || a.starts_with(anchor)),
            "docs/ROBUSTNESS.md lost the '{anchor}' section"
        );
    }
    // And the observability doc: the taxonomy, format, export and
    // percentile sections are linked from the README, ARCHITECTURE and
    // the trace-layer rustdoc.
    let observability = fs::read_to_string(root.join("docs/OBSERVABILITY.md")).unwrap();
    let required = [
        "event-taxonomy-and-reason-codes",
        "the-umt-format",
        "chrome-trace-export",
        "latency-percentiles",
    ];
    for anchor in required {
        assert!(
            anchors(&observability).iter().any(|a| a == anchor || a.starts_with(anchor)),
            "docs/OBSERVABILITY.md lost the '{anchor}' section"
        );
    }
    // And the replay doc: the format/semantics/generator/corpus
    // sections are linked from the README, OBSERVABILITY and the
    // replay-layer rustdoc.
    let replay = fs::read_to_string(root.join("docs/REPLAY.md")).unwrap();
    let required = [
        "the-replay-section",
        "replay-semantics",
        "what-is-and-isnt-reproduced",
        "generator-parameter-reference",
        "adding-a-corpus-trace",
    ];
    for anchor in required {
        assert!(
            anchors(&replay).iter().any(|a| a == anchor || a.starts_with(anchor)),
            "docs/REPLAY.md lost the '{anchor}' section"
        );
    }
    // And the platforms doc: the regime taxonomy, the counter model,
    // the engine-degradation map and the scope/fidelity sections are
    // linked from the README, ARCHITECTURE and the platform/um rustdoc.
    let platforms = fs::read_to_string(root.join("docs/PLATFORMS.md")).unwrap();
    let required = [
        "the-three-migration-regimes",
        "the-access-counter-model",
        "engine-degradation-on-the-coherent-platform",
        "what-is-and-isnt-reproduced",
        "the-differential-test-layer",
    ];
    for anchor in required {
        assert!(
            anchors(&platforms).iter().any(|a| a == anchor || a.starts_with(anchor)),
            "docs/PLATFORMS.md lost the '{anchor}' section"
        );
    }
    // And the analysis doc: the lattice, happens-before, diagnostic
    // table and limitations sections are linked from the README,
    // REPLAY and the analysis-layer rustdoc.
    let analysis = fs::read_to_string(root.join("docs/ANALYSIS.md")).unwrap();
    let required = [
        "the-allocation-state-lattice",
        "happens-before-timelines-and-ordering-edges",
        "severities-and-gates",
        "diagnostic-reference",
        "what-vet-cannot-prove",
    ];
    for anchor in required {
        assert!(
            anchors(&analysis).iter().any(|a| a == anchor || a.starts_with(anchor)),
            "docs/ANALYSIS.md lost the '{anchor}' section"
        );
    }
}

#[test]
fn slugify_matches_github_rules() {
    assert_eq!(slugify(" The `um::auto` Engine"), "the-umauto-engine");
    assert_eq!(slugify("Worked example"), "worked-example");
    assert_eq!(slugify("Two-level delta_history"), "two-level-delta_history");
}
