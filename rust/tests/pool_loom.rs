//! Loom model-check of [`umbra::util::pool::Pool`].
//!
//! Compiled and run only under `RUSTFLAGS="--cfg loom"` (the
//! `concurrency-models` CI job); a normal `cargo test` sees an empty
//! test target. Loom replaces the pool's `Arc`/`Mutex`/`mpsc`/`thread`
//! with instrumented versions and exhaustively explores every
//! observable interleaving of the worker threads, verifying for *all*
//! schedules what `src/util/pool.rs`'s unit tests check for one:
//!
//! * `try_map` returns results in input order regardless of which
//!   worker picks up which job or which finishes first;
//! * a panicking job is confined to `Err(message)` in its own slot,
//!   every other job still completes, and the pool (its worker threads
//!   survive the caught unwind) remains usable afterwards;
//! * `Drop` joins all workers — no schedule deadlocks or leaks a
//!   thread (loom fails the model if a thread outlives the iteration).
#![cfg(loom)]

use umbra::util::pool::Pool;

/// Two workers racing over three ordered jobs: the result vector must
/// come back in input order under every schedule.
#[test]
fn try_map_preserves_input_order_under_all_interleavings() {
    loom::model(|| {
        let pool = Pool::new(2);
        let out = pool.try_map(vec![10i32, 20, 30], |x| x + 1);
        assert_eq!(out, vec![Ok(11), Ok(21), Ok(31)]);
    });
}

/// A panicking job must not poison its worker or the batch: the other
/// slots complete with `Ok` in order, the panic is reported in place,
/// and the same pool still serves a follow-up batch.
#[test]
fn try_map_isolates_a_panicking_job_under_all_interleavings() {
    loom::model(|| {
        let pool = Pool::new(2);
        let out = pool.try_map(vec![0i32, 1, 2], |x| {
            assert!(x != 1, "job 1 exploded");
            x * 2
        });
        assert_eq!(out.len(), 3);
        assert_eq!(out[0], Ok(0));
        assert!(out[1].as_ref().unwrap_err().contains("exploded"));
        assert_eq!(out[2], Ok(4));
        let again = pool.try_map(vec![5i32], |x| x);
        assert_eq!(again, vec![Ok(5)]);
    });
}

/// Dropping the pool with no submitted work joins the workers cleanly
/// in every schedule (the channel-close handshake has no lost-wakeup).
#[test]
fn drop_joins_idle_workers_under_all_interleavings() {
    loom::model(|| {
        let pool = Pool::new(2);
        drop(pool);
    });
}
