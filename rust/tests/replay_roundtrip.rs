//! Capture → replay fidelity (the tentpole acceptance property): a
//! recorded app run, replayed on the same platform with no overrides,
//! reproduces the originating run's `UmMetrics` and every `Ns`
//! byte-identically — the simulator is deterministic, replay re-issues
//! the identical verb sequence, so the whole-struct equality oracle
//! holds across all six variants, both regimes and both paper
//! platforms. Plus the `umbra synth` determinism property: same seed
//! and parameters are byte-identical, different seeds differ.

use umbra::apps::replay::{replay, ReplayConfig};
use umbra::apps::{AppId, Regime, RunOpts, Variant};
use umbra::platform::PlatformId;
use umbra::sim::synth::{self, SynthParams, SynthPattern};
use umbra::trace::UmtTrace;
use umbra::util::units::MIB;

/// Record one BS run and return its result (program attached).
fn recorded_run(
    platform: PlatformId,
    variant: Variant,
    regime: Regime,
    streams: u32,
) -> umbra::apps::RunResult {
    let app = AppId::Bs.build_for(platform, regime);
    let opts = RunOpts { record: true, streams, ..Default::default() };
    app.run_with(&platform.spec(), variant, &opts)
}

#[test]
fn faithful_replay_is_byte_identical_across_the_matrix() {
    for platform in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        for regime in Regime::ALL {
            for variant in Variant::ALL_WITH_AUTO {
                let original = recorded_run(platform, variant, regime, 1);
                let prog = original.replay.clone().expect("recorded");
                prog.validate().expect("captured program validates");
                let cfg = ReplayConfig::from_program(&prog);
                let replayed = replay(&prog, &cfg, &RunOpts::default());
                let label = format!("{}/{}/{}", platform.name(), variant.name(), regime.name());
                assert_eq!(
                    replayed.metrics, original.metrics,
                    "{label}: UmMetrics must be byte-identical"
                );
                assert_eq!(replayed.kernel_time, original.kernel_time, "{label}: kernel Ns");
                assert_eq!(replayed.kernel_times, original.kernel_times, "{label}: per-launch Ns");
                assert_eq!(replayed.wall_time, original.wall_time, "{label}: wall Ns");
            }
        }
    }
}

#[test]
fn faithful_replay_holds_with_multiple_streams() {
    let original =
        recorded_run(PlatformId::IntelPascal, Variant::UmAuto, Regime::Oversubscribed, 2);
    let prog = original.replay.clone().expect("recorded");
    assert_eq!(prog.streams, 2, "stream count captured in the header");
    let replayed = replay(&prog, &ReplayConfig::from_program(&prog), &RunOpts::default());
    assert_eq!(replayed.metrics, original.metrics);
    assert_eq!(replayed.kernel_times, original.kernel_times);
}

#[test]
fn recapture_of_a_replay_reproduces_the_program() {
    // Replaying with record on yields the same program back — replay
    // is a fixed point of capture.
    let original = recorded_run(PlatformId::IntelPascal, Variant::UmBoth, Regime::InMemory, 1);
    let prog = original.replay.clone().expect("recorded");
    let replayed = replay(
        &prog,
        &ReplayConfig::from_program(&prog),
        &RunOpts { record: true, ..Default::default() },
    );
    assert_eq!(replayed.replay.as_ref(), Some(&prog), "re-capture == input program");
}

#[test]
fn synth_same_seed_is_byte_identical_and_seeds_differ() {
    for pattern in SynthPattern::ALL {
        let params =
            SynthParams { pattern, footprint: 64 * MIB, launches: 24, ..Default::default() };
        let a = synth::generate(&params);
        let b = synth::generate(&params);
        assert_eq!(a, b, "{}: same seed+params must generate identical programs", pattern.name());
        let bytes_a = UmtTrace::for_replay(a.clone(), "t").encode();
        let bytes_b = UmtTrace::for_replay(b, "t").encode();
        assert_eq!(bytes_a, bytes_b, "{}: encoded captures byte-identical", pattern.name());
        let c = synth::generate(&SynthParams { seed: 99, ..params });
        assert_ne!(a, c, "{}: a different seed must generate a different program", pattern.name());
    }
}

#[test]
fn synth_programs_replay_deterministically() {
    // Live-run determinism for the generator path: two replays of the
    // same generated program agree on everything.
    let prog = synth::generate(&SynthParams {
        pattern: SynthPattern::Zipf { hot_fraction: 0.1, hot_bias: 0.8 },
        footprint: 128 * MIB,
        launches: 32,
        ..Default::default()
    });
    let cfg = ReplayConfig::from_program(&prog);
    let a = replay(&prog, &cfg, &RunOpts::default());
    let b = replay(&prog, &cfg, &RunOpts::default());
    assert_eq!(a.metrics, b.metrics);
    assert_eq!(a.kernel_times, b.kernel_times);
    assert_eq!(a.wall_time, b.wall_time);
}
