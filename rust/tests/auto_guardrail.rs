//! Guardrail for the `um::auto` policy engine (the `UM Auto` variant):
//! a closed-loop policy that is sometimes much worse than plain UM is
//! worse than no policy at all. At small footprints,
//!
//! * `UM Auto` must never be more than a small tolerance slower than
//!   plain `UM` — every app, both headline platforms, both regimes;
//! * on the sequential-streaming apps on Intel-PCIe it must be strictly
//!   *faster* (the engine rediscovering the paper's prefetch win).

use umbra::apps::{AppId, Regime, Variant};
use umbra::platform::{PlatformId, PlatformSpec};
use umbra::um::{EvictorKind, PredictorKind};
use umbra::util::units::MIB;

/// Kernel time of one (app, variant) run on `plat` at `footprint`.
fn kernel_ns(app: AppId, plat: &PlatformSpec, variant: Variant, footprint: u64) -> f64 {
    app.build(footprint).run(plat, variant, false).kernel_time.0 as f64
}

/// Auto must stay within `tol` of plain UM.
fn assert_within(app: AppId, plat: &PlatformSpec, footprint: u64, tol: f64) {
    let um = kernel_ns(app, plat, Variant::Um, footprint);
    let auto = kernel_ns(app, plat, Variant::UmAuto, footprint);
    assert!(
        auto <= um * tol,
        "{} on {}: UmAuto {:.3} ms vs Um {:.3} ms exceeds tolerance {tol}",
        app.name(),
        plat.name,
        auto / 1e6,
        um / 1e6,
    );
}

#[test]
fn auto_never_much_worse_than_um_in_memory() {
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let plat = plat_id.spec();
        for app in AppId::ALL {
            assert_within(app, &plat, 64 * MIB, 1.05);
        }
    }
}

#[test]
fn auto_never_much_worse_than_um_oversubscribed() {
    // Shrink device memory so ~150% oversubscription is cheap to
    // simulate (same trick as the oversubscription integration tests).
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let mut plat = plat_id.spec();
        plat.gpu.mem_capacity = 128 * MIB;
        plat.gpu.reserved = 0;
        let footprint = (plat.gpu.usable() as f64 * 1.5) as u64;
        for app in AppId::ALL {
            if !app.in_paper_matrix(plat_id, Regime::Oversubscribed) {
                continue;
            }
            assert_within(app, &plat, footprint, 1.10);
        }
    }
}

#[test]
fn auto_beats_um_on_sequential_streaming_apps_on_intel_pcie() {
    // The paper's Intel-PCIe finding: prefetch wins for the apps that
    // stream large host-initialized inputs. The engine must rediscover
    // it online.
    let plat = PlatformId::IntelPascal.spec();
    for app in [AppId::Bs, AppId::Cg, AppId::Conv1, AppId::Fdtd3d] {
        let um = kernel_ns(app, &plat, Variant::Um, 64 * MIB);
        let auto = kernel_ns(app, &plat, Variant::UmAuto, 64 * MIB);
        assert!(
            auto < um,
            "{}: UmAuto {:.3} ms should beat Um {:.3} ms on Intel-PCIe",
            app.name(),
            auto / 1e6,
            um / 1e6,
        );
    }
}

#[test]
fn guardrail_holds_for_the_heuristic_predictor_too() {
    // The default platform spec runs the learned predictor (every test
    // above exercises it); the `--predictor heuristic` compatibility
    // mode must satisfy the same contract.
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let mut plat = plat_id.spec();
        plat.um.auto_predictor = PredictorKind::Heuristic;
        for app in [AppId::Bs, AppId::Cg, AppId::Fdtd3d] {
            assert_within(app, &plat, 64 * MIB, 1.05);
        }
    }
    let mut plat = PlatformId::IntelPascal.spec();
    plat.um.auto_predictor = PredictorKind::Heuristic;
    let um = kernel_ns(AppId::Bs, &plat, Variant::Um, 64 * MIB);
    let auto = kernel_ns(AppId::Bs, &plat, Variant::UmAuto, 64 * MIB);
    assert!(auto < um, "heuristic mode keeps the Intel-PCIe streaming win");
}

#[test]
fn guardrail_holds_with_learned_eviction_oversubscribed() {
    // `--evictor learned` must stay inside the same oversubscribed
    // bounds as the default engine on BOTH platforms — in particular
    // the P9 pathology cells must not regress (mispredicted dead
    // ranges there would re-create exactly the §IV-B churn the advise
    // guard exists to avoid).
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let mut plat = plat_id.spec();
        plat.gpu.mem_capacity = 128 * MIB;
        plat.gpu.reserved = 0;
        plat.um.evictor = EvictorKind::Learned;
        let footprint = (plat.gpu.usable() as f64 * 1.5) as u64;
        for app in AppId::ALL {
            if !app.in_paper_matrix(plat_id, Regime::Oversubscribed) {
                continue;
            }
            assert_within(app, &plat, footprint, 1.10);
        }
    }
}

#[test]
fn guardrail_holds_with_learned_eviction_in_memory() {
    // In-memory the learned evictor must be a strict no-op (no
    // eviction pressure, no hints): the usual bound applies trivially
    // but is pinned here so a future gating bug cannot slip through.
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let mut plat = plat_id.spec();
        plat.um.evictor = EvictorKind::Learned;
        for app in [AppId::Bs, AppId::Cg, AppId::Fdtd3d] {
            assert_within(app, &plat, 64 * MIB, 1.05);
        }
    }
}

#[test]
fn auto_guardrail_holds_on_the_coherent_platform() {
    // On Grace-Coherent the engine degrades to threshold hints only
    // (no prefetch, no advises — docs/PLATFORMS.md); that residual
    // actuation must never cost more than the usual bound over plain
    // UM, in memory or oversubscribed.
    let plat = PlatformId::GraceCoherent.spec();
    for app in AppId::ALL {
        assert_within(app, &plat, 64 * MIB, 1.10);
    }
    let mut plat = PlatformId::GraceCoherent.spec();
    plat.gpu.mem_capacity = 128 * MIB;
    plat.gpu.reserved = 0;
    let footprint = (plat.gpu.usable() as f64 * 1.5) as u64;
    for app in AppId::ALL {
        if !app.in_paper_matrix(PlatformId::GraceCoherent, Regime::Oversubscribed) {
            continue;
        }
        assert_within(app, &plat, footprint, 1.10);
    }
}

#[test]
fn watchdog_never_trips_on_healthy_coherent_runs() {
    // With no fault injection there is no harm signal, and the benefit
    // ledger (remote bytes the counter migrations avoided) keeps the
    // circuit breaker closed — a trip here would mean the coherent
    // degradation starves the watchdog of benefit and it strangles a
    // healthy engine.
    for regime in Regime::ALL {
        let mut plat = PlatformId::GraceCoherent.spec();
        let footprint = match regime {
            Regime::InMemory => 64 * MIB,
            Regime::Oversubscribed => {
                plat.gpu.mem_capacity = 128 * MIB;
                plat.gpu.reserved = 0;
                (plat.gpu.usable() as f64 * 1.5) as u64
            }
        };
        for app in AppId::ALL {
            if !app.in_paper_matrix(PlatformId::GraceCoherent, regime) {
                continue;
            }
            let r = app.build(footprint).run(&plat, Variant::UmAuto, false);
            assert_eq!(
                r.metrics.wd_trips,
                0,
                "{} {} on Grace-Coherent: breaker tripped on a healthy run",
                app.name(),
                regime.name(),
            );
        }
    }
}

#[test]
fn learned_predictor_decision_quality_reported() {
    // The learned mode's accuracy/coverage counters feed the suite
    // JSON trajectory; make sure real apps populate them and that
    // prediction quality is sane on the streaming apps.
    let plat = PlatformId::IntelPascal.spec();
    let r = AppId::Bs.build(64 * MIB).run(&plat, Variant::UmAuto, false);
    assert!(r.metrics.auto_predict_queries > 0, "learned mode consulted");
    let acc = r.metrics.prediction_accuracy();
    assert!(
        acc.is_nan() || acc >= 0.5,
        "when predictions resolved, most bytes were consumed: {acc:.2}"
    );
}

#[test]
fn auto_engine_reports_activity() {
    // The counters that feed the CSV trajectory are actually populated.
    let plat = PlatformId::IntelPascal.spec();
    let r = AppId::Bs.build(64 * MIB).run(&plat, Variant::UmAuto, false);
    assert!(r.metrics.auto_decisions > 0, "engine made decisions");
    assert!(r.metrics.auto_prefetched_bytes > 0, "escalation moved bytes");
    // And plain UM runs carry no auto noise.
    let r = AppId::Bs.build(64 * MIB).run(&plat, Variant::Um, false);
    assert_eq!(r.metrics.auto_decisions, 0);
    assert_eq!(r.metrics.auto_prefetched_bytes, 0);
}
