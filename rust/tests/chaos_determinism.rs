//! Contracts of the chaos layer (`sim::inject`) and the `um::auto`
//! watchdog (docs/ROBUSTNESS.md):
//!
//! * **Determinism under injection** — the same `(scenario, seed)`
//!   produces byte-identical runs (every `Ns` output and the full
//!   `UmMetrics`) for all six variants on both headline platforms and
//!   the coherent Grace-class platform (including chaos aimed at the
//!   C2C link the coherent regime leans on).
//! * **Disabled oracle** — with `ChaosScenario::Off` the injection seed
//!   is inert: runs are byte-identical across seeds, consume no chaos
//!   budget, and a healthy run never trips the watchdog.
//! * **Graceful degradation** — under every active scenario `UM Auto`
//!   completes and stays within the auto-guardrail tolerance of plain
//!   UM *under the same injection*.
//! * **Trip and recover** — a flaky-prefetch episode trips the watchdog
//!   (rung-down, bounded retries) and, once the fault clears, the
//!   backed-off re-arm probes climb the ladder back to `Full`.

use umbra::apps::{AppId, Variant};
use umbra::mem::PageRange;
use umbra::platform::{PlatformId, PlatformSpec};
use umbra::sim::{ChaosScenario, InjectConfig};
use umbra::um::{UmRuntime, WatchdogMode};
use umbra::util::units::{Ns, MIB};

/// Platform spec with `scenario` armed (default chaos seed).
fn chaotic(plat_id: PlatformId, scenario: ChaosScenario) -> PlatformSpec {
    let mut plat = plat_id.spec();
    plat.um.inject = InjectConfig { scenario, ..InjectConfig::default() };
    plat
}

const ALL_SCENARIOS: [ChaosScenario; 6] = [
    ChaosScenario::Off,
    ChaosScenario::LinkDegrade,
    ChaosScenario::FlakyPrefetch,
    ChaosScenario::EccRetire,
    ChaosScenario::FaultNoise,
    ChaosScenario::Storm,
];

#[test]
fn same_seed_same_run_all_variants_both_platforms() {
    for plat_id in
        [PlatformId::IntelPascal, PlatformId::P9Volta, PlatformId::GraceCoherent]
    {
        for scenario in ALL_SCENARIOS {
            let plat = chaotic(plat_id, scenario);
            for variant in Variant::ALL_WITH_AUTO {
                let a = AppId::Bs.build(32 * MIB).run(&plat, variant, false);
                let b = AppId::Bs.build(32 * MIB).run(&plat, variant, false);
                let label =
                    format!("{}/{}/{}", plat_id.name(), variant.name(), scenario.name());
                assert_eq!(a.kernel_time, b.kernel_time, "{label}: kernel time");
                assert_eq!(a.kernel_times, b.kernel_times, "{label}: launches");
                assert_eq!(a.wall_time, b.wall_time, "{label}: wall time");
                assert_eq!(a.metrics, b.metrics, "{label}: UmMetrics");
            }
        }
    }
}

#[test]
fn same_seed_same_run_oversubscribed_under_storm() {
    // The eviction paths (including ECC retirement pressure) replay
    // identically too.
    let mut plat = chaotic(PlatformId::IntelPascal, ChaosScenario::Storm);
    plat.gpu.mem_capacity = 128 * MIB;
    plat.gpu.reserved = 0;
    let footprint = (plat.gpu.usable() as f64 * 1.5) as u64;
    for variant in [Variant::Um, Variant::UmAuto] {
        let a = AppId::Bs.build(footprint).run(&plat, variant, false);
        let b = AppId::Bs.build(footprint).run(&plat, variant, false);
        assert_eq!(a.kernel_time, b.kernel_time, "{}: kernel time", variant.name());
        assert_eq!(a.metrics, b.metrics, "{}: UmMetrics", variant.name());
    }
}

#[test]
fn scenario_off_ignores_the_seed_and_spends_no_budget() {
    // The differential oracle for "injection disabled = byte-identical":
    // with `Off`, the seed must be completely inert (no RNG consumed,
    // no hook fired), so two runs with *different* seeds are identical.
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        for variant in [Variant::Um, Variant::UmAuto] {
            let plat_a = plat_id.spec(); // default seed, scenario Off
            let mut plat_b = plat_id.spec();
            plat_b.um.inject =
                InjectConfig { scenario: ChaosScenario::Off, seed: 0xDEAD_BEEF };
            let a = AppId::Bs.build(32 * MIB).run(&plat_a, variant, false);
            let b = AppId::Bs.build(32 * MIB).run(&plat_b, variant, false);
            let label = format!("{}/{}", plat_id.name(), variant.name());
            assert_eq!(a.kernel_time, b.kernel_time, "{label}: kernel time");
            assert_eq!(a.metrics, b.metrics, "{label}: UmMetrics");
            assert_eq!(a.metrics.chaos_failed_prefetch_bytes, 0, "{label}: no chaos");
        }
    }
}

#[test]
fn watchdog_never_trips_on_a_healthy_run() {
    // Sequential streaming apps with injection off: the ledger is all
    // benefit, so the engine must stay at `Full` the whole run.
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let plat = plat_id.spec();
        for app in [AppId::Bs, AppId::Cg, AppId::Fdtd3d] {
            let r = app.build(64 * MIB).run(&plat, Variant::UmAuto, false);
            let label = format!("{}/{}", plat_id.name(), app.name());
            assert_eq!(r.metrics.wd_trips, 0, "{label}: no trips");
            assert_eq!(r.metrics.wd_degraded_windows, 0, "{label}: never degraded");
            assert_eq!(r.metrics.wd_retries, 0, "{label}: nothing to retry");
        }
    }
}

#[test]
fn coherent_link_chaos_replays_byte_identically() {
    // LinkDegrade and Storm hit the C2C fabric that services *every*
    // host-resident access on Grace-Coherent — the regime where link
    // chaos has the widest blast radius. Same seed, same bytes; and
    // the coherent accounting keeps flowing under degradation.
    for scenario in [ChaosScenario::LinkDegrade, ChaosScenario::Storm] {
        let plat = chaotic(PlatformId::GraceCoherent, scenario);
        for variant in [Variant::Um, Variant::UmAuto] {
            let a = AppId::Bs.build(32 * MIB).run(&plat, variant, false);
            let b = AppId::Bs.build(32 * MIB).run(&plat, variant, false);
            let label = format!("grace-coherent/{}/{}", variant.name(), scenario.name());
            assert_eq!(a.kernel_time, b.kernel_time, "{label}: kernel time");
            assert_eq!(a.kernel_times, b.kernel_times, "{label}: launches");
            assert_eq!(a.metrics, b.metrics, "{label}: UmMetrics");
            assert!(
                a.metrics.remote_access_bytes > 0,
                "{label}: remote servicing continues under link chaos"
            );
        }
    }
    // Oversubscribed under Storm: counter migrations, evictions and
    // chaos interleave — still byte-identical.
    let mut plat = chaotic(PlatformId::GraceCoherent, ChaosScenario::Storm);
    plat.gpu.mem_capacity = 128 * MIB;
    plat.gpu.reserved = 0;
    let footprint = (plat.gpu.usable() as f64 * 1.5) as u64;
    for variant in [Variant::Um, Variant::UmAuto] {
        let a = AppId::Bs.build(footprint).run(&plat, variant, false);
        let b = AppId::Bs.build(footprint).run(&plat, variant, false);
        assert_eq!(a.kernel_time, b.kernel_time, "{}: kernel time", variant.name());
        assert_eq!(a.metrics, b.metrics, "{}: UmMetrics", variant.name());
    }
}

#[test]
fn auto_stays_within_guardrail_under_every_scenario() {
    // Graceful degradation, quantified: under the same injection, the
    // self-defending engine completes and stays within the (chaos)
    // guardrail of plain UM — the watchdog turns "policy under faults"
    // into "no worse than no policy".
    const TOL: f64 = 1.10;
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        for scenario in ChaosScenario::ALL_ACTIVE {
            let plat = chaotic(plat_id, scenario);
            for app in [AppId::Bs, AppId::Cg, AppId::Fdtd3d] {
                let um = app.build(64 * MIB).run(&plat, Variant::Um, false);
                let auto = app.build(64 * MIB).run(&plat, Variant::UmAuto, false);
                assert!(
                    (auto.kernel_time.0 as f64) <= (um.kernel_time.0 as f64) * TOL,
                    "{}/{}/{}: UmAuto {:.3} ms vs Um {:.3} ms exceeds {TOL}",
                    plat_id.name(),
                    app.name(),
                    scenario.name(),
                    auto.kernel_time.0 as f64 / 1e6,
                    um.kernel_time.0 as f64 / 1e6,
                );
            }
        }
    }
}

#[test]
fn flaky_prefetch_trips_the_watchdog_and_recovers_after_the_fault_clears() {
    // Drive the runtime directly with a sequential sweep so the engine
    // escalates to bulk prefetch while the flaky-prefetch budget makes
    // those pieces fail: the harm ledger trips the ladder down. The
    // budget is finite (the fault clears), so a second sweep's clean
    // windows let the backed-off probes climb back to `Full`.
    let mut plat = PlatformId::IntelPascal.spec();
    plat.um.inject = InjectConfig {
        scenario: ChaosScenario::FlakyPrefetch,
        ..InjectConfig::default()
    };
    let mut r = UmRuntime::new(&plat);
    r.enable_auto();
    let id = r.malloc_managed("x", 512 * MIB);
    let full = r.space.get(id).full();
    r.host_access(id, full, true, Ns::ZERO);
    let pages = full.end;
    let step = 32u32;
    let mut t = Ns::ZERO;
    for sweep in 0..2 {
        let mut pos = 0u32;
        while pos < pages {
            let range = PageRange::new(pos, (pos + step).min(pages));
            t = r.gpu_access(id, range, sweep == 0, t).done;
            pos += step;
        }
    }
    let m = &r.metrics;
    assert!(m.chaos_failed_prefetch_bytes > 0, "the scenario actually fired");
    assert!(m.wd_trips >= 1, "sustained harm tripped the ladder: {m:?}");
    assert!(m.wd_degraded_windows >= 1, "time was spent degraded");
    assert!(m.wd_retries >= 1, "failed pieces were retried with backoff");
    assert!(
        m.wd_recoveries >= 1,
        "the watchdog re-armed after the fault cleared: {} trips, {} recoveries",
        m.wd_trips,
        m.wd_recoveries
    );
    let eng = r.auto_engine().expect("engine");
    assert_eq!(
        eng.watchdog.mode(),
        WatchdogMode::Full,
        "fully recovered by the end of the clean sweep"
    );
}
