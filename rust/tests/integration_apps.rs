//! Integration tests over the application layer: the paper's findings
//! as executable assertions, at reduced footprints for speed (the
//! full-scale versions run in `examples/end_to_end.rs` and the benches).

use umbra::apps::{AppId, Regime, Variant};
use umbra::coordinator::{run_cell, Cell, Suite, SuiteConfig};
use umbra::platform::PlatformId;
use umbra::util::units::Ns;

#[test]
fn every_app_runs_every_variant_on_every_platform_small() {
    // Smoke the full matrix (including the UmAuto policy engine) at
    // 64 MiB footprints.
    for app in AppId::ALL {
        let a = app.build(64 * 1024 * 1024);
        for plat in PlatformId::ALL {
            let spec = plat.spec();
            for variant in Variant::ALL_WITH_AUTO {
                let r = a.run(&spec, variant, false);
                assert!(
                    r.kernel_time > Ns::ZERO,
                    "{}/{}/{} produced zero kernel time",
                    app.name(),
                    plat.name(),
                    variant.name()
                );
                assert!(r.wall_time >= r.kernel_time);
            }
        }
    }
}

#[test]
fn explicit_baseline_is_fastest_kernel_in_memory() {
    // In-memory, the explicit version's *kernel time* lower-bounds all
    // UM variants (its copies are outside the measured window).
    for app in [AppId::Bs, AppId::Conv1, AppId::Fdtd3d] {
        let a = app.build(128 * 1024 * 1024);
        let spec = PlatformId::IntelVolta.spec();
        let explicit = a.run(&spec, Variant::Explicit, false).kernel_time;
        for variant in Variant::UM_ONLY {
            let t = a.run(&spec, variant, false).kernel_time;
            assert!(
                t >= explicit,
                "{}: {} ({t}) beat explicit ({explicit})",
                app.name(),
                variant.name()
            );
        }
    }
}

#[test]
fn um_both_combines_advise_and_prefetch_benefits_in_memory() {
    // §IV-A: "when both advises and prefetch are used together, it
    // generally outperforms the performance of applications using only
    // advises or prefetch."
    let suite = Suite::run(&SuiteConfig {
        apps: vec![AppId::Matmul, AppId::Conv0],
        platforms: vec![PlatformId::P9Volta],
        variants: Variant::ALL.to_vec(),
        regimes: vec![Regime::InMemory],
        reps: 1,
        threads: 2,
        ..Default::default()
    });
    for app in [AppId::Matmul, AppId::Conv0] {
        let t = |v| {
            suite
                .get4(app, PlatformId::P9Volta, v, Regime::InMemory)
                .unwrap()
                .kernel_time
                .mean
        };
        let both = t(Variant::UmBoth);
        assert!(
            both <= t(Variant::Um),
            "{}: Both should beat basic UM",
            app.name()
        );
        // "generally outperforms" — allow small slack vs the best single
        // technique, but it must not be grossly worse.
        let best_single = t(Variant::UmAdvise).min(t(Variant::UmPrefetch));
        assert!(
            both.0 as f64 <= best_single.0 as f64 * 1.15,
            "{}: Both ({both}) much worse than best single ({best_single})",
            app.name()
        );
    }
}

#[test]
fn graph500_reports_per_iteration_statistics() {
    // §III-B: "An exception is Graph500, where we report the average
    // and standard deviation of BFS iterations."
    let cell = Cell {
        app: AppId::Graph500,
        platform: PlatformId::IntelPascal,
        variant: Variant::Um,
        regime: Regime::InMemory,
    };
    let r = run_cell(cell, 2, false);
    assert!(r.per_launch.n >= 24, "per-BFS-level samples (got {})", r.per_launch.n);
    assert!(r.per_launch.mean > Ns::ZERO);
    assert!(r.per_launch.std > Ns::ZERO, "levels have different frontier sizes");
}

#[test]
fn oversubscription_all_apps_complete_correctly() {
    // §IV-B: "all applications execute correctly, even when running out
    // of GPU memory."
    for app in AppId::ALL {
        if !app.in_paper_matrix(PlatformId::IntelPascal, Regime::Oversubscribed) {
            continue;
        }
        // Tiny platform so 150% oversubscription is cheap to simulate.
        let mut plat = PlatformId::IntelPascal.spec();
        plat.gpu.mem_capacity = 128 * 1024 * 1024;
        plat.gpu.reserved = 0;
        let a = app.build((plat.gpu.usable() as f64 * 1.5) as u64);
        for variant in Variant::UM_ONLY {
            let r = a.run(&plat, variant, false);
            assert!(r.kernel_time > Ns::ZERO, "{}/{}", app.name(), variant.name());
        }
    }
}

#[test]
fn breakdown_sums_are_consistent_with_metrics() {
    let cell = Cell {
        app: AppId::Cg,
        platform: PlatformId::IntelPascal,
        variant: Variant::Um,
        regime: Regime::InMemory,
    };
    let r = run_cell(cell, 1, true);
    let m = &r.last.metrics;
    let b = &r.breakdown;
    assert_eq!(b.h2d_bytes, m.h2d_bytes, "trace and metrics agree on H2D bytes");
    assert_eq!(b.d2h_bytes, m.d2h_bytes, "trace and metrics agree on D2H bytes");
    assert_eq!(b.fault_stall, m.fault_stall, "trace and metrics agree on stalls");
}

#[test]
fn suite_parallel_equals_serial() {
    let config = SuiteConfig {
        apps: vec![AppId::Bs, AppId::Fdtd3d],
        platforms: vec![PlatformId::IntelPascal],
        variants: vec![Variant::Um, Variant::UmAdvise],
        regimes: vec![Regime::InMemory],
        reps: 1,
        threads: 4,
        ..Default::default()
    };
    let parallel = Suite::run(&config);
    let serial = Suite::run(&SuiteConfig { threads: 1, ..config.clone() });
    for (cell, r) in &serial.results {
        let p = parallel.get(cell).expect("cell present");
        assert_eq!(p.kernel_time.mean, r.kernel_time.mean, "{}", cell.label());
    }
}
