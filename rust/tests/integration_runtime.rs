//! Integration tests for the PJRT runtime: artifacts load, execute,
//! and validate against Rust references. These need `make artifacts`;
//! they skip (with a note) if the artifacts are missing so `cargo test`
//! stays usable before the first build.

use std::path::Path;

use umbra::apps::AppId;
use umbra::runtime::{validate_all, validate_app, Input, PjrtRuntime};

fn runtime() -> Option<PjrtRuntime> {
    if !Path::new("artifacts/manifest.txt").exists() {
        eprintln!("skipping PJRT tests: run `make artifacts` first");
        return None;
    }
    Some(PjrtRuntime::open(Path::new("artifacts")).expect("open artifacts"))
}

#[test]
fn all_artifacts_validate_against_rust_references() {
    let Some(rt) = runtime() else { return };
    let reports = validate_all(&rt).expect("validation");
    assert_eq!(reports.len(), 6);
    for r in &reports {
        assert!(r.passed, "{} failed", r.model);
    }
}

#[test]
fn every_app_has_a_validating_artifact() {
    let Some(rt) = runtime() else { return };
    for app in AppId::ALL {
        let artifact = app.build(1024 * 1024).artifact();
        assert!(rt.manifest.get(artifact).is_some(), "{}: artifact '{artifact}' missing", app.name());
        let rep = validate_app(&rt, artifact).expect(artifact);
        assert!(rep.passed);
    }
}

#[test]
fn executable_cache_reuses_compilation() {
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get("fdtd_step").unwrap();
    let n = spec.args[0].n_elements();
    let grid = vec![1.0f32; n];
    // First call compiles; subsequent calls hit the cache and must be
    // significantly faster.
    let t0 = std::time::Instant::now();
    let first = rt.execute("fdtd_step", &[Input::F32(grid.clone())]).unwrap();
    let cold = t0.elapsed();
    let t1 = std::time::Instant::now();
    let second = rt.execute("fdtd_step", &[Input::F32(grid)]).unwrap();
    let warm = t1.elapsed();
    assert_eq!(first[0], second[0], "deterministic execution");
    assert!(warm < cold, "cache not effective: warm {warm:?} vs cold {cold:?}");
}

#[test]
fn fdtd_uniform_field_fixed_point_through_pjrt() {
    // Independent physical invariant executed through the whole
    // AOT+PJRT stack: a uniform field stays uniform under the stencil.
    let Some(rt) = runtime() else { return };
    let spec = rt.manifest.get("fdtd_step").unwrap();
    let n = spec.args[0].n_elements();
    let out = rt.execute("fdtd_step", &[Input::F32(vec![2.0; n])]).unwrap();
    let expected = 2.0 * (0.5 + 6.0 / 12.0);
    for (i, v) in out[0].iter().enumerate() {
        assert!((v - expected).abs() < 1e-5, "point {i}: {v} != {expected}");
    }
}

#[test]
fn matmul_identity_through_pjrt() {
    let Some(rt) = runtime() else { return };
    let dims = &rt.manifest.get("matmul").unwrap().args[0].dims;
    let n = dims[0] as usize;
    let mut eye = vec![0.0f32; n * n];
    for i in 0..n {
        eye[i * n + i] = 1.0;
    }
    let a: Vec<f32> = (0..n * n).map(|i| (i % 97) as f32 * 0.25).collect();
    let out = rt.execute("matmul", &[Input::F32(a.clone()), Input::F32(eye)]).unwrap();
    for (g, w) in out[0].iter().zip(&a) {
        assert!((g - w).abs() < 1e-4);
    }
}

#[test]
fn unknown_model_rejected() {
    let Some(rt) = runtime() else { return };
    assert!(rt.execute("nope", &[]).is_err());
    assert!(validate_app(&rt, "nope").is_err());
}
