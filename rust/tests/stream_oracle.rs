//! Stream-keying contracts of the `(StreamId, AllocId)` engine
//! refactor:
//!
//! * **Entry-point oracle** — `run(trace)` and
//!   `run_with(RunOpts { streams: 1 })` stay bit-identical (every `Ns`
//!   output and the full `UmMetrics`) for all six variants on both
//!   headline platforms in both regimes, and a single-stream `UM Auto`
//!   run leaves engine state keyed by stream 0 only. Note `run` is a
//!   provided wrapper over `run_with`, so this pins the two entry
//!   points against *future* divergence (plus determinism), not
//!   pre-refactor behaviour; the step-by-step behavioural oracle that
//!   replays the pre-refactor engine pipeline access-by-access lives
//!   in `tests/predictor_modes.rs` and runs through the re-keyed
//!   engine unchanged — together they pin the single-stream contract.
//! * **Pollution regression** — two streams interleaving a sequential
//!   and an irregular access pattern over ONE allocation: the
//!   per-stream engine classifies each stream correctly, while the
//!   conflated (allocation-keyed, pre-refactor) window provably loses
//!   the sequential stream — the bug ROADMAP called "polluting each
//!   other's windows".
//! * **Multi-stream determinism** — `streams: 2` runs are
//!   deterministic and populate per-stream counters.

use std::collections::VecDeque;

use umbra::apps::{AppId, Regime, RunOpts, Variant};
use umbra::gpu::StreamId;
use umbra::mem::PageRange;
use umbra::platform::PlatformId;
use umbra::um::auto::pattern::{classify, AccessRecord, Pattern};
use umbra::um::{AutoConfig, UmRuntime};
use umbra::util::units::{Bytes, Ns, MIB};

#[test]
fn single_stream_runs_bit_identical_all_variants_both_platforms() {
    for platform in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        for regime in [Regime::InMemory, Regime::Oversubscribed] {
            for variant in Variant::ALL_WITH_AUTO {
                // §IV-B: no explicit baseline under oversubscription.
                if regime == Regime::Oversubscribed && variant == Variant::Explicit {
                    continue;
                }
                let app = AppId::Bs.build_for(platform, regime);
                let plat = platform.spec();
                let legacy = app.run(&plat, variant, false);
                let opts = RunOpts { trace: false, streams: 1, ..Default::default() };
                let threaded = app.run_with(&plat, variant, &opts);
                let label = format!("{}/{}/{}", platform.name(), variant.name(), regime.name());
                assert_eq!(legacy.kernel_time, threaded.kernel_time, "{label}: kernel time");
                assert_eq!(legacy.kernel_times, threaded.kernel_times, "{label}: launches");
                assert_eq!(legacy.wall_time, threaded.wall_time, "{label}: wall time");
                assert_eq!(legacy.metrics, threaded.metrics, "{label}: UmMetrics");
            }
        }
    }
}

#[test]
fn single_stream_auto_run_keys_state_by_stream_zero_only() {
    // A single-stream UM Auto run must not leak per-stream machinery
    // into observable state: every counter lands in stream 0's slot
    // and the engine's merged view equals the stream-0 view.
    let mut r = UmRuntime::new(&umbra::platform::intel_pascal());
    r.enable_auto_with(AutoConfig::default());
    let id = r.malloc_managed("x", 16 * MIB);
    let full = r.space.get(id).full();
    r.host_access(id, full, true, Ns::ZERO);
    let mut t = Ns::ZERO;
    for i in 0..6u32 {
        t = r.gpu_access(id, PageRange::new(i * 32, (i + 1) * 32), false, t).done;
    }
    let eng = r.auto_engine().unwrap();
    assert_eq!(eng.pattern_of(id), eng.pattern_on(StreamId::DEFAULT, id));
    assert!(!eng.multi_stream());
    for (i, s) in r.metrics.active_streams() {
        assert_eq!(i, 0, "only stream 0 recorded activity: {s:?}");
    }
}

/// The two access patterns of the pollution scenario, as page ranges.
/// Stream A: contiguous forward windows. Stream B: an irregular
/// (+7, +19, +3)-cycle of 2-page accesses in a far page region —
/// forward-moving with every delta larger than the access length, so
/// its own per-stream view never revisits a page (no "wrap"), but with
/// no majority stride either.
fn seq_window(i: u32) -> PageRange {
    PageRange::new(i * 16, (i + 1) * 16)
}

fn irregular_window(i: u32) -> PageRange {
    let mut start = 300u32;
    for k in 0..i {
        start += [7, 19, 3][(k % 3) as usize];
    }
    PageRange::new(start, start + 2)
}

#[test]
fn two_streams_on_one_allocation_classify_per_stream() {
    // Escalation/prediction off: pure observer + classifier, so the
    // test pins classification, not transfer timing.
    let cfg = AutoConfig { escalate: false, predict: false, ..AutoConfig::default() };
    let mut r = UmRuntime::new(&umbra::platform::intel_pascal());
    r.enable_auto_with(cfg);
    let id = r.malloc_managed("shared", 32 * MIB); // 512 pages
    let full = r.space.get(id).full();
    r.host_access(id, full, true, Ns::ZERO);

    let s2 = StreamId(2);
    // Replay of what a single conflated window would have seen: the
    // interleaved ranges with h2d/wrap bookkeeping shared across both
    // streams (exactly the pre-refactor, allocation-keyed observer).
    let mut conflated: VecDeque<AccessRecord> = VecDeque::new();
    let mut seen_end = 0u32;
    let mut t = Ns::ZERO;
    for i in 0..8u32 {
        for (stream, range) in [(StreamId::DEFAULT, seq_window(i)), (s2, irregular_window(i))] {
            let out = r.gpu_access_on(stream, id, range, false, t);
            t = out.done;
            let wrapped = range.start < seen_end;
            seen_end = seen_end.max(range.end);
            conflated.push_back(AccessRecord {
                range,
                write: false,
                h2d_bytes: out.h2d_bytes,
                wrapped,
            });
            if conflated.len() > cfg.window {
                conflated.pop_front();
            }
        }
    }

    // Per-stream keying: each stream's view is classified correctly.
    let eng = r.auto_engine().expect("engine attached");
    assert_eq!(
        eng.pattern_on(StreamId::DEFAULT, id),
        Pattern::Sequential,
        "stream 0's contiguous windows classify sequential"
    );
    assert_eq!(
        eng.pattern_on(s2, id),
        Pattern::Random,
        "stream 2's irregular cycle classifies random"
    );

    // The pollution bug, demonstrated: the conflated window alternates
    // between the two streams' cursors, so the classifier can no
    // longer see the sequential stream at all — on pre-refactor main
    // (one window per allocation) this misclassification drove the
    // whole allocation's policy, killing stream 0's prefetch.
    assert_ne!(
        classify(&conflated),
        Pattern::Sequential,
        "conflated window loses the sequential stream: {conflated:?}"
    );

    // And the engine's byte counters stay per-stream consistent.
    let total: Bytes = r.metrics.per_stream.iter().map(|s| s.auto_prefetched_bytes).sum();
    assert_eq!(r.metrics.auto_prefetched_bytes, total);
}

#[test]
fn two_stream_auto_run_is_deterministic_and_counts_per_stream() {
    let app = AppId::Bs.build_for(PlatformId::IntelPascal, Regime::InMemory);
    let plat = PlatformId::IntelPascal.spec();
    let opts = RunOpts { trace: false, streams: 2, ..Default::default() };
    let a = app.run_with(&plat, Variant::UmAuto, &opts);
    let b = app.run_with(&plat, Variant::UmAuto, &opts);
    assert_eq!(a.kernel_time, b.kernel_time, "multi-stream runs are deterministic");
    assert_eq!(a.metrics, b.metrics);
    // Launches alternate stream 0 and the created compute stream 2
    // (stream 1 is the background prefetch stream).
    assert!(a.metrics.per_stream[0].gpu_accesses > 0, "stream 0 drove accesses");
    assert!(a.metrics.per_stream[2].gpu_accesses > 0, "stream 2 drove accesses");
    assert!(
        a.metrics.per_stream[1].gpu_accesses == 0,
        "background stream launches no kernels"
    );
}
