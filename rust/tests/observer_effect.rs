//! Zero-observer-effect oracle (docs/OBSERVABILITY.md): the trace is a
//! pure observer. Running the same cell with tracing disabled, enabled
//! unbounded, or enabled with a tiny storage cap must produce
//! byte-identical simulated times (`Ns`) and `UmMetrics` — including
//! the percentile histograms, which are fed unconditionally and never
//! through the trace gate.

use umbra::apps::{AppId, RunOpts, RunResult, Variant};
use umbra::platform::{PlatformId, PlatformSpec};
use umbra::util::units::MIB;

/// The three observation modes under test.
fn modes() -> [(&'static str, RunOpts); 3] {
    [
        ("disabled", RunOpts { trace: false, ..Default::default() }),
        ("enabled", RunOpts { trace: true, ..Default::default() }),
        ("capped", RunOpts { trace: true, trace_cap: Some(8), ..Default::default() }),
    ]
}

/// Everything a run reports that must not depend on observation:
/// simulated times and the full metrics block. (The breakdown and the
/// trace itself are observation products and are excluded by design.)
fn observables(r: &RunResult) -> (umbra::util::units::Ns, Vec<umbra::util::units::Ns>, umbra::util::units::Ns, umbra::um::UmMetrics) {
    (r.kernel_time, r.kernel_times.clone(), r.wall_time, r.metrics.clone())
}

fn assert_identical(plat: &PlatformSpec, footprint: u64, label: &str) {
    for variant in Variant::ALL_WITH_AUTO {
        let mut baseline = None;
        for (mode, opts) in modes() {
            let r = AppId::Bs.build(footprint).run_with(plat, variant, &opts);
            let got = observables(&r);
            match &baseline {
                None => baseline = Some((got, mode)),
                Some((want, base_mode)) => {
                    assert_eq!(
                        &got, want,
                        "{label}/{}: trace mode '{mode}' diverged from '{base_mode}'",
                        variant.name()
                    );
                }
            }
            // The modes must also deliver what they promise.
            match mode {
                "disabled" => assert!(r.trace.is_none(), "{label}: no trace when disabled"),
                _ => assert!(r.trace.is_some(), "{label}: trace present when enabled"),
            }
            if mode == "capped" {
                let t = r.trace.as_ref().unwrap();
                assert!(t.len() <= 8, "{label}: cap bounds storage");
            }
        }
    }
}

#[test]
fn tracing_changes_nothing_in_memory() {
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let plat = plat_id.spec();
        assert_identical(&plat, 48 * MIB, &format!("{}/in-memory", plat_id.name()));
    }
}

#[test]
fn tracing_changes_nothing_oversubscribed() {
    // Shrink the GPU so eviction, writeback and (on UM Auto) the
    // watchdog all engage — the paths with the densest instrumentation.
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let mut plat = plat_id.spec();
        plat.gpu.mem_capacity = 128 * MIB;
        plat.gpu.reserved = 0;
        let footprint = (plat.gpu.usable() as f64 * 1.5) as u64;
        assert_identical(&plat, footprint, &format!("{}/oversubscribed", plat_id.name()));
    }
}

#[test]
fn tracing_changes_nothing_under_injection() {
    // Chaos decisions (chaos.*) ride the same gate: an armed scenario
    // with tracing on/off/capped still replays byte-identically.
    let mut plat = PlatformId::IntelPascal.spec();
    plat.um.inject = umbra::sim::InjectConfig {
        scenario: umbra::sim::ChaosScenario::Storm,
        ..Default::default()
    };
    plat.gpu.mem_capacity = 128 * MIB;
    plat.gpu.reserved = 0;
    let footprint = (plat.gpu.usable() as f64 * 1.5) as u64;
    assert_identical(&plat, footprint, "Intel-Pascal/storm");
}
