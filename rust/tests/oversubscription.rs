//! Oversubscription-focused integration tests: eviction mechanics,
//! thrash detection, failure injection, and the paper's §IV-B findings
//! at controlled scale.

use umbra::apps::{AppId, Regime, Variant};
use umbra::mem::Residency;
use umbra::platform::{intel_pascal, p9_volta, PlatformId, PlatformSpec};
use umbra::um::{Advise, Loc, UmRuntime};
use umbra::util::units::{Ns, MIB};

fn shrunk(mut plat: PlatformSpec, cap_mib: u64) -> PlatformSpec {
    plat.gpu.mem_capacity = cap_mib * MIB;
    plat.gpu.reserved = 0;
    plat
}

#[test]
fn lru_eviction_order_is_oldest_first() {
    let mut r = UmRuntime::new(&shrunk(intel_pascal(), 64));
    let a = r.malloc_managed("a", 30 * MIB);
    let b = r.malloc_managed("b", 30 * MIB);
    let c = r.malloc_managed("c", 30 * MIB);
    for id in [a, b, c] {
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
    }
    let (fa, fb, fc) = (r.space.get(a).full(), r.space.get(b).full(), r.space.get(c).full());
    let t1 = r.gpu_access(a, fa, false, Ns(0)).done;
    let t2 = r.gpu_access(b, fb, false, t1).done;
    r.gpu_access(c, fc, false, t2); // must evict a (the oldest)
    let alloc_a = r.space.get(a);
    let a_on_dev = alloc_a.pages.count(fa, |p| p.residency.on_device());
    let alloc_b = r.space.get(b);
    let b_on_dev = alloc_b.pages.count(fb, |p| p.residency.on_device());
    assert!(a_on_dev < alloc_a.n_pages(), "oldest allocation partially evicted");
    assert_eq!(b_on_dev, alloc_b.n_pages(), "recently used allocation survives");
    r.check_residency_invariant().unwrap();
}

#[test]
fn writeback_vs_drop_decision_follows_host_copy_validity() {
    let mut r = UmRuntime::new(&shrunk(intel_pascal(), 64));
    // d: duplicated read-mostly data (host copy valid -> free drop).
    let d = r.malloc_managed("dup", 30 * MIB);
    // m: migrated data (host copy stale -> writeback).
    let m = r.malloc_managed("mig", 30 * MIB);
    let n = r.malloc_managed("new", 50 * MIB);
    for id in [d, m, n] {
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
    }
    let fd = r.space.get(d).full();
    r.mem_advise(d, fd, Advise::ReadMostly, Ns::ZERO);
    let t1 = r.gpu_access(d, fd, false, Ns(0)).done; // duplicates
    let fm = r.space.get(m).full();
    let t2 = r.gpu_access(m, fm, false, t1).done; // migrates
    let before_wb = r.metrics.writeback_bytes;
    let before_drop = r.metrics.dropped_bytes;
    let fnn = r.space.get(n).full();
    r.gpu_access(n, fnn, false, t2); // evicts both d and m content
    assert!(r.metrics.dropped_bytes > before_drop, "duplicates dropped free");
    assert!(r.metrics.writeback_bytes > before_wb, "migrated pages written back");
    r.check_residency_invariant().unwrap();
}

#[test]
fn thrash_ratio_detects_p9_advise_pathology() {
    // The paper's Fig. 8c/8d observation — "intense data movement in
    // both directions" — as a metric: D2H/H2D ratio under advise on P9
    // far exceeds basic UM's.
    let plat = PlatformId::P9Volta;
    let app = AppId::Bs.build_for(plat, Regime::Oversubscribed);
    let spec = plat.spec();
    let um = app.run(&spec, Variant::Um, false);
    let adv = app.run(&spec, Variant::UmAdvise, false);
    assert!(
        adv.metrics.link_bytes() > 2 * um.metrics.link_bytes(),
        "advise moves far more data: {} vs {}",
        adv.metrics.link_bytes(),
        um.metrics.link_bytes()
    );
    assert!(adv.metrics.fault_stall > um.metrics.fault_stall * 2);
}

#[test]
fn unpinned_neighbor_self_evicts_around_pinned_region() {
    // A large pinned region constrains the unpinned allocation to a
    // tiny window: it thrashes against *itself*, never touching the
    // pinned pages (the LRU honours the pin).
    let mut r = UmRuntime::new(&shrunk(p9_volta(), 64));
    let a = r.malloc_managed("pinned", 60 * MIB);
    let fa = r.space.get(a).full();
    r.mem_advise(a, fa, Advise::PreferredLocation(Loc::Gpu), Ns::ZERO);
    r.host_access(a, fa, true, Ns::ZERO); // ATS init -> on device, pinned
    let b = r.malloc_managed("other", 32 * MIB);
    let fb = r.space.get(b).full();
    r.host_access(b, fb, true, Ns::ZERO);
    r.gpu_access(b, fb, true, Ns(0)); // write => must go local
    assert!(r.dev.evictions > 0, "b churns through the 4 MiB window");
    assert_eq!(r.dev.forced_pinned_evictions, 0, "pin respected");
    let alloc_a = r.space.get(a);
    assert_eq!(
        alloc_a.pages.count(fa, |p| p.residency.on_device()),
        alloc_a.n_pages(),
        "pinned region untouched"
    );
    r.check_residency_invariant().unwrap();
}

#[test]
fn forced_pinned_eviction_when_everything_is_pinned() {
    let mut r = UmRuntime::new(&shrunk(p9_volta(), 64));
    let a = r.malloc_managed("p1", 60 * MIB);
    let b = r.malloc_managed("p2", 32 * MIB);
    for id in [a, b] {
        let full = r.space.get(id).full();
        r.mem_advise(id, full, Advise::PreferredLocation(Loc::Gpu), Ns::ZERO);
    }
    r.host_access(a, r.space.get(a).full(), true, Ns::ZERO); // fills device, pinned
    let fb = r.space.get(b).full();
    r.host_access(b, fb, true, Ns::ZERO); // overflows to host
    r.gpu_access(b, fb, true, Ns(0)); // pinned-vs-pinned: must force
    assert!(r.dev.forced_pinned_evictions > 0);
    r.check_residency_invariant().unwrap();
}

#[test]
fn graph500_oversubscription_on_intel_pascal_only() {
    // Matches Table I: the only Graph500 oversubscription config.
    let cellcfg = AppId::Graph500.build_for(PlatformId::IntelPascal, Regime::Oversubscribed);
    let spec = PlatformId::IntelPascal.spec();
    let r = cellcfg.run(&spec, Variant::Um, false);
    assert!(r.kernel_time > Ns::ZERO);
    assert!(r.metrics.evicted_chunks > 0, "BFS at 150% must evict");
}

#[test]
fn eviction_never_leaves_dangling_residency() {
    // Failure-injection-flavored churn: interleave conflicting advises
    // with accesses under heavy pressure; the accounting must hold.
    let mut r = UmRuntime::new(&shrunk(intel_pascal(), 48));
    let a = r.malloc_managed("a", 40 * MIB);
    let b = r.malloc_managed("b", 40 * MIB);
    for id in [a, b] {
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
    }
    let (fa, fb) = (r.space.get(a).full(), r.space.get(b).full());
    let mut now = Ns::ZERO;
    for i in 0..6 {
        now = r.gpu_access(a, fa, i % 2 == 0, now).done;
        r.mem_advise(b, fb, if i % 2 == 0 { Advise::ReadMostly } else { Advise::UnsetReadMostly }, now);
        now = r.gpu_access(b, fb, false, now).done;
        r.mem_advise(a, fa, Advise::PreferredLocation(if i % 2 == 0 { Loc::Gpu } else { Loc::Cpu }), now);
        r.check_residency_invariant().unwrap();
    }
    // Nothing is resident twice, nothing leaked.
    let total_resident: u64 = r
        .space
        .iter()
        .map(|al| al.pages.count(al.full(), |p| p.residency.on_device()) as u64 * umbra::mem::PAGE_SIZE)
        .sum();
    assert_eq!(total_resident, r.dev.used());
}

#[test]
fn oversub_kernel_time_exceeds_in_memory() {
    for plat in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let spec = plat.spec();
        let app_im = AppId::Fdtd3d.build_for(plat, Regime::InMemory);
        let app_os = AppId::Fdtd3d.build_for(plat, Regime::Oversubscribed);
        let im = app_im.run(&spec, Variant::Um, false).kernel_time;
        let os = app_os.run(&spec, Variant::Um, false).kernel_time;
        assert!(os > im, "{}: oversub {os} <= in-memory {im}", plat.name());
    }
}

#[test]
fn evicted_then_reaccessed_data_returns_intact_state() {
    let mut r = UmRuntime::new(&shrunk(intel_pascal(), 64));
    let a = r.malloc_managed("a", 40 * MIB);
    let b = r.malloc_managed("b", 40 * MIB);
    for id in [a, b] {
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
    }
    let (fa, fb) = (r.space.get(a).full(), r.space.get(b).full());
    let t1 = r.gpu_access(a, fa, true, Ns(0)).done; // dirty a
    let t2 = r.gpu_access(b, fb, false, t1).done; // evicts chunks of a (writeback)
    let out = r.gpu_access(a, fa, false, t2); // bring a back
    assert!(out.h2d_bytes > 0, "a re-migrates");
    let alloc = r.space.get(a);
    // After writeback + re-migration the pages are device-resident and
    // clean (host copy was refreshed by the writeback).
    assert!(alloc.pages.count(fa, |p| p.residency == Residency::Device) > 0);
    r.check_residency_invariant().unwrap();
}
