//! The committed-corpus decision-quality regression suite.
//!
//! Every trace under `corpora/` is decoded (with the canonical
//! round-trip verified), structurally validated, and replayed on all
//! three spec platforms — both fault-driven paper machines plus the
//! coherent Grace-class system — in both predictor modes. Numeric
//! expectations live
//! in `corpora/expectations.json` (refreshed from `umbra replay
//! corpora --out`, see docs/REPLAY.md); the perturbation tests pin the
//! suite's sensitivity — deliberately breaking a policy constant such
//! as `min_confidence` must change the replayed metrics.

use std::fs;
use std::path::{Path, PathBuf};

use umbra::apps::replay::{replay, ReplayConfig};
use umbra::apps::RunOpts;
use umbra::platform::PlatformId;
use umbra::trace::{ReplayProgram, UmtTrace};
use umbra::um::{AutoConfig, PredictorKind};
use umbra::util::jsonout::Json;

fn corpora_dir() -> PathBuf {
    // CARGO_MANIFEST_DIR is <repo>/rust.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root").join("corpora")
}

/// All committed corpus traces, sorted by file name.
fn corpus() -> Vec<(String, ReplayProgram)> {
    let mut files: Vec<PathBuf> = fs::read_dir(corpora_dir())
        .expect("corpora/ exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "umt"))
        .collect();
    files.sort();
    files
        .iter()
        .map(|f| {
            let bytes = fs::read(f).unwrap_or_else(|e| panic!("read {}: {e}", f.display()));
            assert!(
                bytes.len() < 100 * 1024,
                "{}: {} bytes exceeds the 100 KiB corpus budget",
                f.display(),
                bytes.len()
            );
            let ut = UmtTrace::decode(&bytes)
                .unwrap_or_else(|e| panic!("{}: decode failed: {e}", f.display()));
            assert_eq!(ut.encode(), bytes, "{}: decode→re-encode byte-identical", f.display());
            let prog = ut
                .replay
                .unwrap_or_else(|| panic!("{}: corpus trace has no replay section", f.display()));
            prog.validate().unwrap_or_else(|e| panic!("{}: invalid program: {e}", f.display()));
            let stem = f.file_stem().expect("stem").to_string_lossy().into_owned();
            (stem, prog)
        })
        .collect()
}

fn config(prog: &ReplayProgram, platform: PlatformId, predictor: PredictorKind) -> ReplayConfig {
    ReplayConfig { platform, predictor, ..ReplayConfig::from_program(prog) }
}

#[test]
fn corpus_covers_the_regime_classes() {
    let stems: Vec<String> = corpus().into_iter().map(|(s, _)| s).collect();
    assert!(stems.len() >= 8, "starter corpus has 8 traces, found {stems:?}");
    for required in [
        "seq_stream",
        "cyclic_oversub",
        "random",
        "multi_stream",
        "adv_zipf",
        "adv_bursty",
        "adv_chase",
        "adv_tenant",
    ] {
        assert!(stems.iter().any(|s| s == required), "corpus lost the '{required}' trace");
    }
}

#[test]
fn every_trace_replays_on_both_platforms_and_predictors() {
    for (stem, prog) in corpus() {
        for platform in
            [PlatformId::IntelPascal, PlatformId::P9Volta, PlatformId::GraceCoherent]
        {
            for predictor in [PredictorKind::Heuristic, PredictorKind::Learned] {
                let cfg = config(&prog, platform, predictor);
                let r = replay(&prog, &cfg, &RunOpts::default());
                let label = format!("{stem}/{}/{}", platform.name(), predictor.name());
                assert!(r.kernel_time.0 > 0, "{label}: zero kernel time");
                assert_eq!(
                    r.kernel_times.len(),
                    prog.launches(),
                    "{label}: one timing per launch"
                );
                assert!(r.wall_time >= r.kernel_time, "{label}: wall >= kernel");
            }
        }
    }
}

#[test]
fn corpus_replay_is_deterministic() {
    for (stem, prog) in corpus() {
        for platform in [PlatformId::IntelPascal, PlatformId::GraceCoherent] {
            let cfg = config(&prog, platform, PredictorKind::Learned);
            let a = replay(&prog, &cfg, &RunOpts::default());
            let b = replay(&prog, &cfg, &RunOpts::default());
            let label = format!("{stem}/{}", platform.name());
            assert_eq!(a.metrics, b.metrics, "{label}: metrics drift across replays");
            assert_eq!(a.kernel_times, b.kernel_times, "{label}: timings drift across replays");
        }
    }
}

/// The coherent platform's no-fault contract holds for every corpus
/// trace: whatever the workload shape, a Grace-Coherent replay services
/// host-resident GPU accesses remotely (no fault groups from them) and
/// any data that reaches the device got there by access-counter
/// migration or explicit prefetch — never by a page-fault group.
#[test]
fn corpus_replays_faultlessly_on_the_coherent_platform() {
    for (stem, prog) in corpus() {
        let cfg = config(&prog, PlatformId::GraceCoherent, PredictorKind::Learned);
        let r = replay(&prog, &cfg, &RunOpts::default());
        assert_eq!(
            r.metrics.gpu_fault_groups, 0,
            "{stem}: fault groups on the coherent platform"
        );
        assert!(
            r.metrics.remote_access_bytes > 0,
            "{stem}: a replayed workload must touch host-resident data remotely"
        );
    }
}

/// Compare replayed metrics against `corpora/expectations.json`. An
/// empty `traces` list is the bootstrap state (schema checked, numeric
/// pinning dormant); once entries exist, every one must match a
/// replayed (trace, platform, predictor) tuple — a stale expectation
/// is a failure, never a silent skip.
#[test]
fn replayed_metrics_match_the_committed_expectations() {
    let path = corpora_dir().join("expectations.json");
    let text = fs::read_to_string(&path).expect("corpora/expectations.json exists");
    let json = Json::parse(&text).expect("expectations.json parses");
    let tolerance = json.get("tolerance").and_then(Json::as_f64).expect("tolerance field");
    let expected = json.get("traces").and_then(Json::as_arr).expect("traces array");
    if expected.is_empty() {
        // Bootstrap: nothing pinned yet. The other tests in this file
        // still gate structure, determinism and sensitivity.
        return;
    }
    let corpus = corpus();
    let mut checked = 0usize;
    for e in expected {
        let stem = e.get("trace").and_then(Json::as_str).expect("trace name");
        let plat = e.get("platform").and_then(Json::as_str).expect("platform name");
        let pred = e.get("predictor").and_then(Json::as_str).expect("predictor name");
        let platform = PlatformId::parse(plat).unwrap_or_else(|| panic!("bad platform '{plat}'"));
        let predictor =
            PredictorKind::parse(pred).unwrap_or_else(|| panic!("bad predictor '{pred}'"));
        let (_, prog) = corpus
            .iter()
            .find(|(s, _)| s == stem)
            .unwrap_or_else(|| panic!("expectation for unknown trace '{stem}'"));
        let r = replay(prog, &config(prog, platform, predictor), &RunOpts::default());
        let label = format!("{stem}/{plat}/{pred}");
        // Kernel time pins within the relative tolerance band (exact
        // on refresh; the band absorbs deliberate re-calibrations).
        let want = e.get("kernel_ns").and_then(Json::as_f64).expect("kernel_ns");
        let got = r.kernel_time.0 as f64;
        assert!(
            (got - want).abs() <= want * tolerance,
            "{label}: kernel_ns {got} outside ±{tolerance} of pinned {want}"
        );
        // Decision-quality metrics pin within an absolute band.
        for (field, got) in [
            ("accuracy", r.metrics.prediction_accuracy()),
            ("coverage", r.metrics.prediction_coverage()),
        ] {
            let Some(want) = e.get(field).and_then(Json::as_f64) else { continue };
            if want.is_nan() || got.is_nan() {
                continue;
            }
            assert!(
                (got - want).abs() <= tolerance,
                "{label}: {field} {got:.4} outside ±{tolerance} of pinned {want:.4}"
            );
        }
        if let Some(want) = e.get("learned_predictions").and_then(Json::as_f64) {
            let got = r.metrics.auto_learned_predictions as f64;
            assert!(
                (got - want).abs() <= want.max(1.0) * tolerance,
                "{label}: learned_predictions {got} outside ±{tolerance} of pinned {want}"
            );
        }
        checked += 1;
    }
    assert!(checked > 0, "expectations present but none were checked");
}

#[test]
fn perturbing_min_confidence_changes_the_replayed_metrics() {
    // The acceptance criterion for the regression suite: deliberately
    // breaking a policy constant must show up. min_confidence = 2.0 is
    // unsatisfiable (confidence caps at 1.0), so the learned predictor
    // can never fire.
    let (_, prog) = corpus()
        .into_iter()
        .find(|(s, _)| s == "adv_chase")
        .expect("adv_chase trace present");
    let cfg = config(&prog, PlatformId::IntelPascal, PredictorKind::Learned);
    let healthy = replay(&prog, &cfg, &RunOpts::default());
    assert!(
        healthy.metrics.auto_learned_predictions > 0,
        "the chase stride cycle must be learnable by the delta table"
    );
    let perturbed_cfg = ReplayConfig {
        auto_cfg: Some(AutoConfig { min_confidence: 2.0, ..AutoConfig::default() }),
        ..cfg
    };
    let perturbed = replay(&prog, &perturbed_cfg, &RunOpts::default());
    assert_eq!(
        perturbed.metrics.auto_learned_predictions, 0,
        "unsatisfiable confidence gate must silence the learned predictor"
    );
    assert_ne!(
        perturbed.metrics, healthy.metrics,
        "the regression suite must detect the perturbation"
    );
}

#[test]
fn chase_trace_separates_the_predictors() {
    // The adversarial chase pattern exists precisely because the two
    // predictors disagree on it: the delta table learns the stride
    // cycle, the sequential heuristic cannot.
    let (_, prog) = corpus()
        .into_iter()
        .find(|(s, _)| s == "adv_chase")
        .expect("adv_chase trace present");
    let learned = replay(
        &prog,
        &config(&prog, PlatformId::IntelPascal, PredictorKind::Learned),
        &RunOpts::default(),
    );
    let heuristic = replay(
        &prog,
        &config(&prog, PlatformId::IntelPascal, PredictorKind::Heuristic),
        &RunOpts::default(),
    );
    assert_ne!(
        learned.metrics, heuristic.metrics,
        "predictor modes must be distinguishable on the chase trace"
    );
    assert_eq!(
        heuristic.metrics.auto_learned_predictions, 0,
        "heuristic mode never emits learned predictions"
    );
}
