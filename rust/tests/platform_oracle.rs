//! The cross-platform differential oracle.
//!
//! Two contracts pin the coherent Grace-class platform model
//! (docs/PLATFORMS.md) against the original paper platforms:
//!
//! 1. **The paper platforms are frozen.** Every coherent-only knob
//!    (`UmPolicy::coherent`, `counter_group_pages`,
//!    `counter_threshold`) must be inert on the three fault-driven
//!    specs — mutating them cannot move a single metric or nanosecond,
//!    and no paper-platform run may report coherent traffic.
//! 2. **The coherent platform honours its no-fault regime.** Plain UM
//!    runs service host-resident GPU accesses remotely (zero fault
//!    groups, non-zero remote bytes) and migrate data only through the
//!    access-counter path, whose volume is monotone in the threshold
//!    knob.

use umbra::apps::{AppId, Regime, Variant};
use umbra::platform::{PlatformId, PlatformSpec};
use umbra::util::units::MIB;

/// Small representative app set: a sequential streamer, an iterative
/// solver, and the random-access graph search — the three access
/// shapes the paper's matrix distinguishes.
const APPS: [AppId; 3] = [AppId::Bs, AppId::Cg, AppId::Graph500];

/// Shrink device memory so ~150% oversubscription is cheap to
/// simulate (same trick as the oversubscription integration tests).
fn oversubscribe(plat: &mut PlatformSpec) -> u64 {
    plat.gpu.mem_capacity = 128 * MIB;
    plat.gpu.reserved = 0;
    (plat.gpu.usable() as f64 * 1.5) as u64
}

/// Footprint for `regime`, shrinking the spec in place when
/// oversubscribing.
fn footprint_for(plat: &mut PlatformSpec, regime: Regime) -> u64 {
    match regime {
        Regime::InMemory => 64 * MIB,
        Regime::Oversubscribed => oversubscribe(plat),
    }
}

#[test]
fn coherent_knobs_are_inert_on_the_paper_platforms() {
    // The differential guard: flipping the counter knobs to aggressive
    // values must leave every paper-platform cell — all six variants,
    // both regimes — byte-identical, because nothing outside
    // `policy.coherent` may consult them.
    for plat_id in PlatformId::PAPER {
        for regime in Regime::ALL {
            for app in APPS {
                if !app.in_paper_matrix(plat_id, regime) {
                    continue;
                }
                let mut base = plat_id.spec();
                let footprint = footprint_for(&mut base, regime);
                let mut tuned = base;
                tuned.um.counter_group_pages = 4;
                tuned.um.counter_threshold = 1;
                for variant in Variant::ALL_WITH_AUTO {
                    let a = app.build(footprint).run(&base, variant, false);
                    let b = app.build(footprint).run(&tuned, variant, false);
                    let label = format!(
                        "{}/{}/{}/{}",
                        plat_id.name(),
                        regime.name(),
                        app.name(),
                        variant.name()
                    );
                    assert_eq!(a.metrics, b.metrics, "{label}: counter knobs moved metrics");
                    assert_eq!(
                        a.kernel_times, b.kernel_times,
                        "{label}: counter knobs moved kernel timings"
                    );
                    assert_eq!(
                        a.metrics.remote_access_bytes, 0,
                        "{label}: remote C2C traffic on a fault-driven platform"
                    );
                    assert_eq!(a.metrics.counter_migrations, 0, "{label}: counter migration");
                    assert_eq!(
                        a.metrics.counter_threshold_crossings, 0,
                        "{label}: threshold crossing"
                    );
                }
            }
        }
    }
}

#[test]
fn coherent_cells_are_deterministic_across_variants_and_regimes() {
    // Same-seed byte-identity on the new platform, every variant, both
    // regimes — the property the paper-platform suite has always had.
    for regime in Regime::ALL {
        let mut plat = PlatformId::GraceCoherent.spec();
        let footprint = footprint_for(&mut plat, regime);
        for variant in Variant::ALL_WITH_AUTO {
            let a = AppId::Bs.build(footprint).run(&plat, variant, false);
            let b = AppId::Bs.build(footprint).run(&plat, variant, false);
            let label = format!("{}/{}", regime.name(), variant.name());
            assert_eq!(a.metrics, b.metrics, "{label}: metrics drift");
            assert_eq!(a.kernel_times, b.kernel_times, "{label}: timing drift");
        }
    }
}

#[test]
fn coherent_um_runs_take_zero_fault_groups() {
    // The defining property of the coherent regime: host-resident data
    // is serviced remotely at line granularity, so plain UM (no advise
    // can re-route it onto the fault path) never replays the far-fault
    // machinery — in memory or oversubscribed, hand-tuned or with the
    // auto engine in the loop.
    for regime in Regime::ALL {
        let mut plat = PlatformId::GraceCoherent.spec();
        let footprint = footprint_for(&mut plat, regime);
        for app in APPS {
            for variant in [Variant::Um, Variant::UmAuto] {
                let r = app.build(footprint).run(&plat, variant, false);
                let label = format!("{}/{}/{}", regime.name(), app.name(), variant.name());
                assert_eq!(r.metrics.gpu_fault_groups, 0, "{label}: fault groups");
                assert!(
                    r.metrics.remote_access_bytes > 0,
                    "{label}: UM kernels must touch host-resident data remotely"
                );
            }
        }
    }
}

#[test]
fn counter_migrations_monotone_in_the_threshold_knob() {
    // Raising the access-counter threshold can only delay or suppress
    // migrations, never create new ones: a group that accumulates T
    // touches has necessarily accumulated T' < T first. The migrated
    // volume must therefore be non-increasing in the knob, with the
    // sentinel 0 disabling the path outright.
    let mut migrations = Vec::new();
    for threshold in [1u32, 2, 4, 8, 16] {
        let mut plat = PlatformId::GraceCoherent.spec();
        plat.um.counter_threshold = threshold;
        let r = AppId::Bs.build(64 * MIB).run(&plat, Variant::Um, false);
        assert_eq!(r.metrics.gpu_fault_groups, 0, "t={threshold}: fault groups");
        migrations.push((threshold, r.metrics.counter_migrations));
    }
    assert!(
        migrations[0].1 > 0,
        "threshold 1 must migrate something on a streaming app: {migrations:?}"
    );
    for w in migrations.windows(2) {
        assert!(
            w[1].1 <= w[0].1,
            "migrations must be non-increasing in the threshold: {migrations:?}"
        );
    }
    let mut plat = PlatformId::GraceCoherent.spec();
    plat.um.counter_threshold = 0;
    let r = AppId::Bs.build(64 * MIB).run(&plat, Variant::Um, false);
    assert_eq!(r.metrics.counter_migrations, 0, "threshold 0 disables counter migration");
    assert_eq!(r.metrics.counter_threshold_crossings, 0, "no crossings when disabled");
    assert!(r.metrics.remote_access_bytes > 0, "everything stays remote when disabled");
}

#[test]
fn coherent_platform_is_a_spec_platform_but_not_a_paper_platform() {
    // The matrix bookkeeping the differential layer leans on.
    assert_eq!(PlatformId::ALL.len(), 4);
    assert_eq!(PlatformId::PAPER.len(), 3);
    assert!(!PlatformId::PAPER.contains(&PlatformId::GraceCoherent));
    assert!(PlatformId::GraceCoherent.is_coherent());
    for plat_id in PlatformId::PAPER {
        assert!(!plat_id.is_coherent(), "{} is fault-driven", plat_id.name());
        assert!(!plat_id.spec().um.coherent);
    }
    let grace = PlatformId::GraceCoherent.spec();
    assert!(grace.um.coherent);
    assert!(grace.um.counter_threshold > 0, "counter migration enabled out of the box");
    assert!(grace.um.counter_group_pages > 0);
}
