//! Mutation self-test for `umbra vet` (docs/ANALYSIS.md).
//!
//! Two halves of one property:
//!
//! * **Soundness of the corpus**: every committed `corpora/*.umt` and
//!   every `umbra synth` pattern vets completely clean — the analyzer
//!   has no false positives on the programs the repo actually ships.
//! * **Sensitivity**: for every diagnostic class, one *targeted verb
//!   mutation* of a clean corpus trace makes vet report exactly that
//!   code and nothing else. Each mutation is the smallest realistic
//!   corruption of the class it exercises (a retargeted read, a widened
//!   window, a dropped sync, a write under `ReadMostly`), so the tests
//!   double as worked examples of what each code means.
//!
//! Every mutation starts from the decoded bytes of a committed trace,
//! so the expected codes are byte-deterministic — no randomness, no
//! replay, no timing.

use std::path::{Path, PathBuf};

use umbra::analysis::{self, vet};
use umbra::gpu::AccessKind;
use umbra::mem::{AllocId, PageRange};
use umbra::platform::PlatformId;
use umbra::sim::{synth, SynthParams, SynthPattern};
use umbra::trace::replay::{ReplayAccess, ReplayOp, ReplayProgram};
use umbra::trace::UmtTrace;
use umbra::um::{Advise, Loc};
use umbra::util::units::GIB;

fn corpora_dir() -> PathBuf {
    // CARGO_MANIFEST_DIR is <repo>/rust.
    Path::new(env!("CARGO_MANIFEST_DIR")).parent().expect("repo root").join("corpora")
}

/// Decode one committed corpus trace's replay program.
fn corpus(stem: &str) -> ReplayProgram {
    let path = corpora_dir().join(format!("{stem}.umt"));
    let bytes = std::fs::read(&path).unwrap_or_else(|e| panic!("{}: {e}", path.display()));
    UmtTrace::decode(&bytes)
        .unwrap_or_else(|e| panic!("{stem}: {e}"))
        .replay
        .unwrap_or_else(|| panic!("{stem}: no replay section"))
}

/// The distinct diagnostic codes vet reports for a program.
fn codes(prog: &ReplayProgram) -> Vec<&'static str> {
    vet(prog).codes()
}

/// Assert a mutated program reports *exactly* one code.
fn assert_exactly(prog: &ReplayProgram, code: &str) {
    let report = vet(prog);
    assert_eq!(report.codes(), vec![code], "diagnostics: {:#?}", report.diagnostics);
}

/// The single kernel access of a one-access launch, by op index.
fn access_mut(prog: &mut ReplayProgram, op: usize) -> &mut ReplayAccess {
    match &mut prog.ops[op] {
        ReplayOp::Launch { phases } => &mut phases[0].accesses[0],
        other => panic!("op#{op} is {other:?}, not a launch"),
    }
}

// --- soundness: everything the repo ships vets clean ------------------

#[test]
fn every_committed_corpus_trace_vets_clean() {
    let mut files: Vec<PathBuf> = std::fs::read_dir(corpora_dir())
        .expect("corpora/ exists")
        .filter_map(|e| e.ok().map(|e| e.path()))
        .filter(|p| p.extension().is_some_and(|x| x == "umt"))
        .collect();
    files.sort();
    assert!(files.len() >= 8, "starter corpus has 8 traces");
    for f in &files {
        let prog = UmtTrace::decode(&std::fs::read(f).unwrap())
            .unwrap_or_else(|e| panic!("{}: {e}", f.display()))
            .replay
            .unwrap_or_else(|| panic!("{}: no replay section", f.display()));
        let report = vet(&prog);
        assert!(report.is_clean(), "{}: {:#?}", f.display(), report.diagnostics);
    }
}

#[test]
fn every_synth_pattern_and_seed_vets_clean() {
    for pattern in SynthPattern::ALL {
        for seed in 1..=8 {
            let prog = synth::generate(&SynthParams { pattern, seed, ..Default::default() });
            let report = vet(&prog);
            assert!(report.is_clean(), "{} seed {seed}: {:#?}", pattern.name(), report.diagnostics);
        }
    }
}

// --- sensitivity: one mutation, one code ------------------------------
//
// seq_stream layout: op0 malloc (32768 pages), op1 host_write,
// ops 2..=257 launches, op258 sync, op259 host_read.
// multi_stream layout: ops 0..=3 mallocs (8192 pages each), 4..=7
// host_writes, 8..=263 launches (launch i: stream i%4, alloc i%4),
// op264 sync, op265 host_read(alloc 0).

#[test]
fn retargeted_read_is_vet_alloc_unallocated() {
    let mut p = corpus("seq_stream");
    let ReplayOp::HostRead { alloc, .. } = &mut p.ops[259] else { panic!("op259 is the read") };
    *alloc = AllocId(99);
    assert_exactly(&p, analysis::ALLOC_UNALLOCATED);
}

#[test]
fn widened_window_is_vet_alloc_oob() {
    let mut p = corpus("seq_stream");
    let ReplayOp::HostRead { range, .. } = &mut p.ops[259] else { panic!("op259 is the read") };
    range.end += 1; // 32769 > 32768 pages
    assert_exactly(&p, analysis::ALLOC_OOB);
}

#[test]
fn managed_alloc_flipped_to_device_is_vet_alloc_kind() {
    let mut p = corpus("seq_stream");
    let ReplayOp::MallocManaged { name, size } = p.ops[0].clone() else {
        panic!("op0 is the malloc")
    };
    // Host writes/reads of cudaMalloc memory panic in the executor —
    // the class of corruption vet exists to catch *before* replay.
    p.ops[0] = ReplayOp::MallocDevice { name, size };
    assert_exactly(&p, analysis::ALLOC_KIND);
}

#[test]
fn cleared_access_set_is_vet_alloc_empty_launch() {
    let mut p = corpus("seq_stream");
    let ReplayOp::Launch { phases } = &mut p.ops[2] else { panic!("op2 is a launch") };
    phases.clear();
    assert_exactly(&p, analysis::ALLOC_EMPTY_LAUNCH);
}

#[test]
fn oversized_gpu_prefetch_is_vet_alloc_overcommit() {
    // cyclic_oversub's 6 GiB allocation exceeds Intel-Pascal's usable
    // device memory — prefetching all of it to the GPU cannot co-reside.
    let mut p = corpus("cyclic_oversub");
    p.ops.insert(2, ReplayOp::PrefetchBackground { alloc: AllocId(0), dst: Loc::Gpu });
    assert_exactly(&p, analysis::ALLOC_OVERCOMMIT);
}

#[test]
fn coherent_platform_rewrites_the_overcommit_advice() {
    // The overcommit verdict is platform-aware: on the fault-driven
    // machines the advice is about eviction thrash, on the coherent
    // Grace-class platform it tells the author to drop the prefetch and
    // let the access counters place the hot subset (docs/PLATFORMS.md).
    // Mutating the program's platform byte must flip the wording.
    let overcommit_msg = |p: &ReplayProgram| {
        vet(p)
            .diagnostics
            .into_iter()
            .find(|d| d.code == analysis::ALLOC_OVERCOMMIT)
            .expect("overcommit diagnostic present")
            .message
    };
    let mut p = corpus("cyclic_oversub");
    p.ops.insert(2, ReplayOp::PrefetchBackground { alloc: AllocId(0), dst: Loc::Gpu });
    // Grace's device is larger than the paper GPUs', so grow the
    // allocation until it overcommits both platforms alike.
    let ReplayOp::MallocManaged { size, .. } = &mut p.ops[0] else { panic!("op0 is the malloc") };
    *size = 24 * GIB;
    assert_exactly(&p, analysis::ALLOC_OVERCOMMIT);
    let fault_driven = overcommit_msg(&p);
    p.platform = PlatformId::GraceCoherent;
    assert_exactly(&p, analysis::ALLOC_OVERCOMMIT);
    let coherent = overcommit_msg(&p);
    assert!(
        coherent.contains("access counters") && coherent.contains("coherent"),
        "coherent advice names the counter path: {coherent}"
    );
    assert!(
        fault_driven.contains("thrash eviction") && !fault_driven.contains("access counters"),
        "fault-driven advice unchanged: {fault_driven}"
    );
}

#[test]
fn hint_after_final_launch_is_vet_alloc_dead_verb() {
    let mut p = corpus("seq_stream");
    p.ops.push(ReplayOp::Advise { alloc: AllocId(0), advise: Advise::AccessedBy(Loc::Gpu) });
    assert_exactly(&p, analysis::ALLOC_DEAD_VERB);
}

#[test]
fn overlapping_cross_stream_writes_are_vet_race_ww() {
    // Launches 0 and 1 run on streams 0 and 2; pointing both at the
    // same alloc-0 window as writers leaves no ordering edge between
    // them.
    let mut p = corpus("multi_stream");
    for op in [8, 9] {
        *access_mut(&mut p, op) = ReplayAccess {
            alloc: AllocId(0),
            range: PageRange { start: 0, end: 64 },
            kind: AccessKind::ReadWrite,
            passes_bits: 1.0f64.to_bits(),
        };
    }
    assert_exactly(&p, analysis::RACE_WW);
}

#[test]
fn unordered_write_under_read_is_vet_race_rw() {
    // Launch 0 (stream 0) already reads alloc 0 pages 0..64; making
    // launch 1 (stream 2) *write* that window races the read.
    let mut p = corpus("multi_stream");
    *access_mut(&mut p, 9) = ReplayAccess {
        alloc: AllocId(0),
        range: PageRange { start: 0, end: 64 },
        kind: AccessKind::ReadWrite,
        passes_bits: 1.0f64.to_bits(),
    };
    assert_exactly(&p, analysis::RACE_RW);
}

#[test]
fn dropping_every_sync_surfaces_races() {
    // The ISSUE-style mutation: strip all DeviceSync barriers from the
    // two-stream tenant trace. The host result-read and the wrapping
    // walkers now overlap cross-stream work with no ordering edge.
    // (This mutation legitimately triggers several race pairs, so it
    // asserts the family rather than one exact code.)
    let mut p = corpus("adv_tenant");
    p.ops.retain(|op| !matches!(op, ReplayOp::DeviceSync));
    let report = vet(&p);
    assert!(
        report.codes().iter().any(|c| c.starts_with("vet.race.")),
        "sync-free two-stream trace must race: {:#?}",
        report.diagnostics
    );
}

#[test]
fn write_under_active_readmostly_is_vet_lint_readmostly_write() {
    // seq_stream's every-4th launch writes back; advising ReadMostly
    // right after setup puts those writes under an active replication
    // hint.
    let mut p = corpus("seq_stream");
    p.ops.insert(2, ReplayOp::Advise { alloc: AllocId(0), advise: Advise::ReadMostly });
    assert_exactly(&p, analysis::LINT_READMOSTLY_WRITE);
}

#[test]
fn set_unset_set_cycle_is_vet_lint_advise_churn() {
    let mut p = corpus("seq_stream");
    // Ends unset, so no write ever lands under an active ReadMostly.
    let cycle = [
        Advise::ReadMostly,
        Advise::UnsetReadMostly,
        Advise::ReadMostly,
        Advise::UnsetReadMostly,
    ];
    for (off, advise) in cycle.into_iter().enumerate() {
        p.ops.insert(2 + off, ReplayOp::Advise { alloc: AllocId(0), advise });
    }
    assert_exactly(&p, analysis::LINT_ADVISE_CHURN);
}

#[test]
fn advise_after_prefetch_is_vet_lint_prefetch_order() {
    // random's 2 GiB footprint fits Intel-Pascal, so the bulk prefetch
    // itself is fine — only the ordering is wrong: the pages arrive
    // before the residency hint exists.
    let mut p = corpus("random");
    p.ops.insert(2, ReplayOp::PrefetchBackground { alloc: AllocId(0), dst: Loc::Gpu });
    let advise = Advise::PreferredLocation(Loc::Gpu);
    p.ops.insert(3, ReplayOp::Advise { alloc: AllocId(0), advise });
    assert_exactly(&p, analysis::LINT_PREFETCH_ORDER);
}

#[test]
fn declared_streams_without_launches_is_vet_lint_streams_unused() {
    // Keep the 4-stream header but delete every launch: the rotation
    // can never reach any stream.
    let mut p = corpus("multi_stream");
    p.ops.retain(|op| !matches!(op, ReplayOp::Launch { .. }));
    assert_exactly(&p, analysis::LINT_STREAMS_UNUSED);
}

#[test]
fn orphan_allocation_is_vet_lint_unused_alloc() {
    // Appended last so no existing AllocId shifts.
    let mut p = corpus("seq_stream");
    p.ops.push(ReplayOp::MallocManaged { name: "orphan".into(), size: 64 * 1024 });
    assert_exactly(&p, analysis::LINT_UNUSED_ALLOC);
}

// --- meta: the matrix above covers the whole registry -----------------

#[test]
fn mutation_matrix_covers_every_family_and_at_least_ten_codes() {
    // The exact-code assertions above pin 12 distinct codes (everything
    // in the registry except the race pair exercised by the sync-drop
    // family test). Keep the registry and this file honest about it.
    let exercised = [
        analysis::ALLOC_UNALLOCATED,
        analysis::ALLOC_OOB,
        analysis::ALLOC_KIND,
        analysis::ALLOC_EMPTY_LAUNCH,
        analysis::ALLOC_OVERCOMMIT,
        analysis::ALLOC_DEAD_VERB,
        analysis::RACE_WW,
        analysis::RACE_RW,
        analysis::LINT_READMOSTLY_WRITE,
        analysis::LINT_ADVISE_CHURN,
        analysis::LINT_PREFETCH_ORDER,
        analysis::LINT_STREAMS_UNUSED,
        analysis::LINT_UNUSED_ALLOC,
    ];
    assert!(exercised.len() >= 10);
    for fam in ["vet.alloc.", "vet.race.", "vet.lint."] {
        assert!(exercised.iter().any(|c| c.starts_with(fam)), "{fam} family exercised");
    }
    for (code, _) in analysis::CODES {
        assert!(exercised.contains(&code), "{code} has no mutation test");
    }
}

// --- determinism ------------------------------------------------------

#[test]
fn vet_reports_are_byte_deterministic() {
    for stem in ["seq_stream", "multi_stream", "adv_tenant"] {
        let p = corpus(stem);
        assert_eq!(vet(&p), vet(&p), "{stem}");
    }
    let mut p = corpus("multi_stream");
    p.ops.retain(|op| !matches!(op, ReplayOp::DeviceSync));
    let (a, b) = (vet(&p), vet(&p));
    assert_eq!(a, b, "mutated programs report identically too");
    assert_eq!(codes(&p), codes(&p));
}
