//! Integration tests: UM runtime mechanisms composed across modules,
//! checking the paper's §II semantics end-to-end.

use umbra::mem::{PageRange, Residency};
use umbra::platform::{intel_pascal, intel_volta, p9_volta};
use umbra::um::{Advise, Loc, UmRuntime};
use umbra::util::units::{Ns, GIB, MIB};

fn host_init(r: &mut UmRuntime, id: umbra::mem::AllocId) -> Ns {
    let full = r.space.get(id).full();
    r.host_access(id, full, true, Ns::ZERO).done
}

#[test]
fn full_lifecycle_malloc_advise_prefetch_kernel_readback() {
    let mut r = UmRuntime::new(&intel_pascal());
    r.enable_trace();
    let a = r.malloc_managed("input", 64 * MIB);
    let b = r.malloc_managed("output", 64 * MIB);
    let t0 = host_init(&mut r, a);
    let fa = r.space.get(a).full();
    let fb = r.space.get(b).full();
    r.mem_advise(a, fa, Advise::ReadMostly, t0);
    let t1 = r.prefetch_async(a, fa, Loc::Gpu, t0);
    let g1 = r.gpu_access(a, fa, false, t1);
    let g2 = r.gpu_access(b, fb, true, g1.done);
    let h = r.host_access(b, fb, false, g2.done);
    assert!(h.done > t1);
    // Read-mostly prefetch duplicated; kernel read had zero stall.
    assert_eq!(g1.fault_stall, Ns::ZERO);
    // Output migrated home for the host read.
    assert_eq!(h.d2h_bytes, 64 * MIB);
    r.check_residency_invariant().unwrap();
    // Trace saw both directions.
    use umbra::trace::TraceKind;
    assert!(r.trace.total_bytes(TraceKind::UmMemcpyHtoD) >= 64 * MIB);
    assert!(r.trace.total_bytes(TraceKind::UmMemcpyDtoH) >= 64 * MIB);
}

#[test]
fn paper_fig1_cpu_write_migrates_page_home() {
    // Fig. 1 of the paper: CPU writes to a GPU-resident page; the page
    // is unmapped from the GPU and migrated to the CPU.
    let mut r = UmRuntime::new(&intel_volta());
    let a = r.malloc_managed("x", 4 * MIB);
    let fa = r.space.get(a).full();
    let g = r.gpu_access(a, fa, true, Ns::ZERO); // GPU populates + dirties
    assert_eq!(r.dev.used(), 4 * MIB);
    let h = r.host_access(a, fa, true, g.done);
    assert!(h.done > g.done);
    assert_eq!(r.dev.used(), 0, "page no longer on the device");
    let alloc = r.space.get(a);
    assert_eq!(alloc.pages.count(fa, |p| p.residency == Residency::Host), alloc.n_pages());
    r.check_residency_invariant().unwrap();
}

#[test]
fn advise_interplay_prefetch_unpins_other_location() {
    // §II-C: prefetching to GPU a host-preferred range unpins it; the
    // next GPU access therefore migrates nothing (already there) and
    // later CPU access migrates it back without remote mapping.
    let mut r = UmRuntime::new(&intel_pascal());
    let a = r.malloc_managed("x", 8 * MIB);
    let fa = r.space.get(a).full();
    host_init(&mut r, a);
    r.mem_advise(a, fa, Advise::PreferredLocation(Loc::Cpu), Ns::ZERO);
    // Without prefetch, GPU would zero-copy (remote) due to PREF_HOST.
    let t = r.prefetch_async(a, fa, Loc::Gpu, Ns::ZERO);
    let g = r.gpu_access(a, fa, false, t);
    assert_eq!(g.remote_bytes, 0, "prefetch unpinned; data is local now");
    assert_eq!(g.fault_stall, Ns::ZERO);
    r.check_residency_invariant().unwrap();
}

#[test]
fn p9_ats_full_pipeline_no_migration_at_all() {
    // P9 advise pipeline: placement advises + host init via ATS = the
    // kernel never faults and no UM memcpy ever happens.
    let mut r = UmRuntime::new(&p9_volta());
    r.enable_trace();
    let a = r.malloc_managed("x", 32 * MIB);
    let fa = r.space.get(a).full();
    r.mem_advise(a, fa, Advise::PreferredLocation(Loc::Gpu), Ns::ZERO);
    r.mem_advise(a, fa, Advise::AccessedBy(Loc::Cpu), Ns::ZERO);
    let t = host_init(&mut r, a);
    let g = r.gpu_access(a, fa, true, t);
    assert_eq!(g.fault_stall, Ns::ZERO);
    let h = r.host_access(a, fa, false, g.done);
    assert_eq!(h.d2h_bytes, 0, "CPU reads results over ATS");
    use umbra::trace::TraceKind;
    assert_eq!(r.trace.total_bytes(TraceKind::UmMemcpyHtoD), 0);
    assert_eq!(r.trace.total_bytes(TraceKind::UmMemcpyDtoH), 0);
    assert!(r.metrics.remote_bytes_cpu_to_dev > 0);
    r.check_residency_invariant().unwrap();
}

#[test]
fn mixed_allocations_do_not_interfere() {
    let mut r = UmRuntime::new(&intel_pascal());
    let managed = r.malloc_managed("m", 16 * MIB);
    let device = r.malloc_device("d", 16 * MIB);
    let host = r.malloc_host("h", 16 * MIB);
    host_init(&mut r, managed);
    let fh = r.space.get(host).full();
    r.host_access(host, fh, true, Ns::ZERO);
    r.memcpy_h2d(device, 16 * MIB, Ns::ZERO);
    let fm = r.space.get(managed).full();
    let fd = r.space.get(device).full();
    let g1 = r.gpu_access(managed, fm, false, Ns::ZERO);
    let g2 = r.gpu_access(device, fd, false, g1.done);
    assert!(g1.h2d_bytes > 0, "managed migrates");
    assert_eq!(g2.h2d_bytes, 0, "cudaMalloc never migrates");
    assert_eq!(r.dev.used(), 32 * MIB);
    r.check_residency_invariant().unwrap();
}

#[test]
fn repeated_reset_reproduces_exactly() {
    let mut r = UmRuntime::new(&p9_volta());
    let a = r.malloc_managed("x", 64 * MIB);
    let mut outcomes = Vec::new();
    for _ in 0..3 {
        r.reset_run_state();
        let fa = r.space.get(a).full();
        let t = r.host_access(a, fa, true, Ns::ZERO).done;
        let g = r.gpu_access(a, fa, false, t);
        outcomes.push((t, g.done, g.fault_stall, r.metrics.gpu_fault_groups));
        r.check_residency_invariant().unwrap();
    }
    assert_eq!(outcomes[0], outcomes[1]);
    assert_eq!(outcomes[1], outcomes[2]);
}

#[test]
fn oversized_single_allocation_handled_via_remote_on_p9() {
    // One allocation larger than the whole GPU: P9's driver serves the
    // overflow remotely instead of thrashing.
    let mut r = UmRuntime::new(&p9_volta());
    let a = r.malloc_managed("huge", 20 * GIB);
    let fa = r.space.get(a).full();
    r.host_access(a, fa, true, Ns::ZERO);
    let g = r.gpu_access(a, fa, false, Ns::ZERO);
    assert!(g.remote_bytes > 0);
    assert_eq!(r.dev.evictions, 0);
    r.check_residency_invariant().unwrap();
}

#[test]
fn oversized_single_allocation_thrashes_on_intel() {
    let mut r = UmRuntime::new(&intel_pascal());
    let a = r.malloc_managed("huge", 6 * GIB);
    let fa = r.space.get(a).full();
    r.host_access(a, fa, true, Ns::ZERO);
    let g = r.gpu_access(a, fa, false, Ns::ZERO);
    assert_eq!(g.remote_bytes, 0);
    assert!(r.dev.evictions > 0, "PCIe must evict (self-eviction of the same array)");
    r.check_residency_invariant().unwrap();
}
