//! The `--evictor` mode contracts (`docs/EVICTION.md`):
//!
//! * `--evictor lru` is byte-identical to the pre-knob runtime: for
//!   every variant that has no hint source (all five non-auto
//!   variants, plus `UM Auto` wherever eviction cannot happen) the two
//!   evictors produce identical Ns + `UmMetrics`. Together with the
//!   in-crate half of the oracle (`um::evict::tests::
//!   lru_mode_ignores_stuffed_hints`, which proves the hint seam is
//!   dead code in lru mode) this pins today's behaviour byte-for-byte.
//! * `--evictor learned` is deterministic, and on the oversubscribed
//!   streaming cells it reduces live-evicted bytes (evicted data the
//!   workload still needed) against raw LRU — without breaking the
//!   eviction-count bookkeeping.
//!
//! Shrunken device capacities keep the oversubscribed cells fast, the
//! same trick the oversubscription integration tests use.

use umbra::apps::{AppId, Regime, Variant};
use umbra::platform::{PlatformId, PlatformSpec};
use umbra::um::EvictorKind;
use umbra::util::units::MIB;

/// Kernel time + full metrics of one (app, variant) run on `plat`.
fn run(
    app: AppId,
    plat: &PlatformSpec,
    variant: Variant,
    footprint: u64,
) -> (u64, umbra::um::UmMetrics) {
    let r = app.build(footprint).run(plat, variant, false);
    (r.kernel_time.0, r.metrics)
}

fn with_evictor(plat_id: PlatformId, evictor: EvictorKind, capacity: Option<u64>) -> PlatformSpec {
    let mut plat = plat_id.spec();
    plat.um.evictor = evictor;
    if let Some(cap) = capacity {
        plat.gpu.mem_capacity = cap;
        plat.gpu.reserved = 0;
    }
    plat
}

#[test]
fn lru_is_byte_identical_for_all_variants_without_hint_sources() {
    // All six variants, both headline platforms, both regimes. The
    // learned evictor differs from lru only through engine hints;
    // every configuration here has none (non-auto variants never
    // attach the engine; UM Auto computes hints only under
    // oversubscription, so its in-memory cells must match too).
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        for (regime, capacity, footprint) in [
            (Regime::InMemory, None, 64 * MIB),
            (Regime::Oversubscribed, Some(128 * MIB), 192 * MIB),
        ] {
            for variant in Variant::ALL_WITH_AUTO {
                if variant == Variant::UmAuto && regime == Regime::Oversubscribed {
                    continue; // hints active: covered by the tests below
                }
                if regime == Regime::Oversubscribed
                    && (variant == Variant::Explicit
                        || !AppId::Bs.in_paper_matrix(plat_id, regime))
                {
                    continue; // no oversubscribed Explicit baseline
                }
                let lru = run(
                    AppId::Bs,
                    &with_evictor(plat_id, EvictorKind::Lru, capacity),
                    variant,
                    footprint,
                );
                let learned = run(
                    AppId::Bs,
                    &with_evictor(plat_id, EvictorKind::Learned, capacity),
                    variant,
                    footprint,
                );
                assert_eq!(
                    lru,
                    learned,
                    "{}/{}/{}: evictor must be inert without hints",
                    plat_id.name(),
                    variant.name(),
                    regime.name()
                );
            }
        }
    }
}

#[test]
fn lru_default_matches_explicit_lru_for_auto_oversubscribed() {
    // The default policy IS the lru evictor: pins that shipping
    // behaviour is unchanged unless --evictor learned is requested.
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let default_plat = {
            let mut p = plat_id.spec();
            p.gpu.mem_capacity = 128 * MIB;
            p.gpu.reserved = 0;
            p
        };
        let explicit = with_evictor(plat_id, EvictorKind::Lru, Some(128 * MIB));
        assert_eq!(
            run(AppId::Bs, &default_plat, Variant::UmAuto, 192 * MIB),
            run(AppId::Bs, &explicit, Variant::UmAuto, 192 * MIB),
        );
    }
}

#[test]
fn learned_evictor_is_deterministic() {
    for plat_id in [PlatformId::IntelPascal, PlatformId::P9Volta] {
        let plat = with_evictor(plat_id, EvictorKind::Learned, Some(128 * MIB));
        let a = run(AppId::Bs, &plat, Variant::UmAuto, 192 * MIB);
        let b = run(AppId::Bs, &plat, Variant::UmAuto, 192 * MIB);
        assert_eq!(a, b, "{}: bit-identical across runs", plat_id.name());
    }
}

#[test]
fn learned_reduces_live_evicted_bytes_on_intel_oversubscribed_streaming() {
    // The PCIe side of the study: no remote-map escape hatch, so
    // oversubscribed streaming really churns the evictor. The learned
    // ranker must cut the bytes that were evicted only to be demanded
    // back (and it must never *increase* them).
    let mut improved = false;
    for app in [AppId::Bs, AppId::Fdtd3d] {
        let lru = run(
            app,
            &with_evictor(PlatformId::IntelPascal, EvictorKind::Lru, Some(128 * MIB)),
            Variant::UmAuto,
            192 * MIB,
        )
        .1;
        let learned = run(
            app,
            &with_evictor(PlatformId::IntelPascal, EvictorKind::Learned, Some(128 * MIB)),
            Variant::UmAuto,
            192 * MIB,
        )
        .1;
        assert!(
            learned.evict_live_evicted_bytes <= lru.evict_live_evicted_bytes,
            "{}: learned live-evicted {} > lru {}",
            app.name(),
            learned.evict_live_evicted_bytes,
            lru.evict_live_evicted_bytes,
        );
        improved |= learned.evict_live_evicted_bytes < lru.evict_live_evicted_bytes;
    }
    assert!(improved, "learned eviction must strictly improve at least one streaming cell");
}

#[test]
fn learned_never_worse_on_p9_pathology_cells() {
    // On P9 the engine's advise guard already avoids the §IV-B
    // eviction storm (overflow is remote-mapped), so there is little
    // churn for the ranker to fix — but it must not create any:
    // live-evicted bytes and kernel time both stay no worse.
    for app in [AppId::Bs, AppId::Fdtd3d] {
        let (lru_ns, lru) = run(
            app,
            &with_evictor(PlatformId::P9Volta, EvictorKind::Lru, Some(128 * MIB)),
            Variant::UmAuto,
            192 * MIB,
        );
        let (learned_ns, learned) = run(
            app,
            &with_evictor(PlatformId::P9Volta, EvictorKind::Learned, Some(128 * MIB)),
            Variant::UmAuto,
            192 * MIB,
        );
        assert!(
            learned.evict_live_evicted_bytes <= lru.evict_live_evicted_bytes,
            "{}: P9 live-evicted regressed {} > {}",
            app.name(),
            learned.evict_live_evicted_bytes,
            lru.evict_live_evicted_bytes,
        );
        assert!(
            learned_ns as f64 <= lru_ns as f64 * 1.02,
            "{}: P9 kernel time regressed {learned_ns} vs {lru_ns}",
            app.name(),
        );
    }
}
