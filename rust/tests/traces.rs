//! Trace-layer integration: nvprof-style records, time series, and the
//! figure harness outputs are internally consistent.

use umbra::apps::{AppId, Regime, Variant};
use umbra::bench_harness::figures;
use umbra::coordinator::{run_cell, Cell};
use umbra::platform::PlatformId;
use umbra::trace::{Breakdown, TimeSeries, TraceKind};
use umbra::util::units::Ns;

fn traced(app: AppId, platform: PlatformId, variant: Variant, regime: Regime) -> umbra::coordinator::CellResult {
    run_cell(Cell { app, platform, variant, regime }, 1, true)
}

#[test]
fn trace_bytes_conserved_into_series() {
    let r = traced(AppId::Bs, PlatformId::IntelPascal, Variant::Um, Regime::InMemory);
    let trace = r.last.trace.as_ref().unwrap();
    let series = TimeSeries::from_trace(trace, Ns(1_000_000));
    assert_eq!(series.total_h2d(), trace.total_bytes(TraceKind::UmMemcpyHtoD));
    assert_eq!(series.total_d2h(), trace.total_bytes(TraceKind::UmMemcpyDtoH));
}

#[test]
fn prefetch_trace_shows_bulk_block_shape() {
    // Fig. 5 observation: "When prefetch is applied, data is transferred
    // as a block at a much higher rate" — peak bin rate under prefetch
    // must exceed the fault-driven peak.
    let um = traced(AppId::Bs, PlatformId::IntelPascal, Variant::Um, Regime::InMemory);
    let pf = traced(AppId::Bs, PlatformId::IntelPascal, Variant::UmPrefetch, Regime::InMemory);
    let bin = Ns(10_000_000); // 10 ms bins
    let um_series = TimeSeries::from_trace(um.last.trace.as_ref().unwrap(), bin);
    let pf_series = TimeSeries::from_trace(pf.last.trace.as_ref().unwrap(), bin);
    assert!(
        pf_series.peak_h2d_rate() > um_series.peak_h2d_rate() * 1.5,
        "prefetch peak {:.1} GB/s vs faulted peak {:.1} GB/s",
        pf_series.peak_h2d_rate() / 1e9,
        um_series.peak_h2d_rate() / 1e9
    );
}

#[test]
fn kernel_windows_present_and_ordered() {
    let r = traced(AppId::Cg, PlatformId::P9Volta, Variant::Um, Regime::InMemory);
    let trace = r.last.trace.as_ref().unwrap();
    let kernels: Vec<_> = trace.of_kind(TraceKind::Kernel).collect();
    assert_eq!(kernels.len(), umbra::apps::cg::ITERATIONS);
    for w in kernels.windows(2) {
        assert!(w[1].start >= w[0].end, "kernel windows overlap");
    }
}

#[test]
fn breakdown_matches_trace_totals() {
    let r = traced(AppId::Fdtd3d, PlatformId::IntelPascal, Variant::Um, Regime::Oversubscribed);
    let trace = r.last.trace.as_ref().unwrap();
    let b = Breakdown::from_trace(trace);
    assert_eq!(b.h2d, trace.total_time(TraceKind::UmMemcpyHtoD));
    assert_eq!(b.d2h, trace.total_time(TraceKind::UmMemcpyDtoH));
    assert_eq!(b.fault_stall, trace.total_time(TraceKind::GpuFaultGroup));
    assert!(b.total() > Ns::ZERO);
}

#[test]
fn explicit_variant_has_no_um_memcpys() {
    let r = traced(AppId::Matmul, PlatformId::IntelVolta, Variant::Explicit, Regime::InMemory);
    let trace = r.last.trace.as_ref().unwrap();
    assert_eq!(trace.total_bytes(TraceKind::UmMemcpyHtoD), 0);
    assert_eq!(trace.total_bytes(TraceKind::UmMemcpyDtoH), 0);
    assert!(trace.total_bytes(TraceKind::MemcpyHtoD) > 0, "explicit cudaMemcpy instead");
}

#[test]
fn figure_reports_write_to_disk() {
    let dir = std::env::temp_dir().join("umbra_traces_test");
    let _ = std::fs::remove_dir_all(&dir);
    let report = figures::table1();
    report.write(&dir).unwrap();
    assert!(dir.join("table1.txt").exists());
    assert!(dir.join("csv/table1.csv").exists());
    let _ = std::fs::remove_dir_all(&dir);
}

#[test]
fn fig7_shows_p9_advise_stall_dominance() {
    // The quantitative content of Fig. 7c/7d: under oversubscription on
    // P9, the advise variant's stall time dwarfs basic UM's.
    let um = traced(AppId::Fdtd3d, PlatformId::P9Volta, Variant::Um, Regime::Oversubscribed);
    let adv = traced(AppId::Fdtd3d, PlatformId::P9Volta, Variant::UmAdvise, Regime::Oversubscribed);
    assert!(
        adv.breakdown.fault_stall > um.breakdown.fault_stall * 2,
        "advise stall {} vs UM stall {}",
        adv.breakdown.fault_stall,
        um.breakdown.fault_stall
    );
    // And bidirectional traffic appears (Fig. 8d).
    assert!(adv.breakdown.d2h_bytes > 0);
}
