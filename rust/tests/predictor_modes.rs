//! The `um::auto` predictor-mode contracts:
//!
//! * `--predictor heuristic` reproduces the original (pre-predictor)
//!   engine behaviour bit-identically: a step-by-step differential
//!   oracle replays the classifier rule outside the engine and checks
//!   the engine's issued prefetch bytes against it after every access;
//! * the learned mode covers access patterns the classifier cannot
//!   (and never consults the tables in heuristic mode);
//! * both modes run end-to-end for every app through the same
//!   plumbing the CLI `--predictor` flag uses.

use umbra::apps::{AppId, Regime, Variant};
use umbra::coordinator::{run_cell, run_cell_on, Cell};
use umbra::mem::{PageRange, PAGE_SIZE};
use umbra::platform::{intel_pascal, PlatformId};
use umbra::um::auto::pattern::{classify, AccessRecord, PatternTracker};
use umbra::um::auto::predictor::heuristic_prediction;
use umbra::um::{AutoConfig, PredictorKind, UmRuntime};
use umbra::util::units::{Bytes, Ns, MIB};

/// A runtime with the engine attached in the given mode, one
/// host-initialized 64 MiB managed allocation, escalation disabled so
/// `auto_prefetched_bytes` counts *predictive* prefetch only.
fn prepped(kind: PredictorKind) -> (UmRuntime, umbra::mem::AllocId, u32) {
    let cfg = AutoConfig { escalate: false, predictor: kind, ..AutoConfig::default() };
    let mut r = UmRuntime::new(&intel_pascal());
    r.enable_auto_with(cfg);
    let id = r.malloc_managed("x", 64 * MIB); // 1024 pages
    let full = r.space.get(id).full();
    r.host_access(id, full, true, Ns::ZERO);
    let n_pages = full.end;
    (r, id, n_pages)
}

/// A mixed stream (within the 1024-page allocation): a sequential
/// phase, a forward outlier, a strided phase — exercising
/// Unknown/Random/Sequential/Strided transitions.
fn mixed_stream() -> Vec<PageRange> {
    let mut s: Vec<PageRange> = (0..6).map(|i| PageRange::new(i * 32, (i + 1) * 32)).collect();
    s.push(PageRange::new(700, 710));
    s.extend((0..5).map(|i| PageRange::new(780 + i * 48, 780 + i * 48 + 16)));
    s
}

#[test]
fn heuristic_mode_matches_the_classifier_rule_oracle() {
    let (mut rt, id, n_pages) = prepped(PredictorKind::Heuristic);
    let cfg = AutoConfig::default();

    // The oracle replays the engine's exact observation pipeline
    // (bounded window -> majority-stride classify -> hysteresis
    // tracker) and the PR 2 prediction rule, plus a page-granular
    // residency model to turn each predicted range into the bytes the
    // engine must move (only host-resident pages transfer; nothing in
    // this in-memory setup evicts).
    let mut window: std::collections::VecDeque<AccessRecord> = std::collections::VecDeque::new();
    let mut tracker = PatternTracker::default();
    let mut seen_end = 0u32;
    let mut resident = vec![false; n_pages as usize];
    let mut expected_total: Bytes = 0;

    let mut t = Ns::ZERO;
    for r in mixed_stream() {
        let out = rt.gpu_access(id, r, false, t);
        t = out.done;

        // -- oracle: observe exactly as um::auto::observer does -------
        let wrapped = r.start < seen_end;
        seen_end = seen_end.max(r.end);
        window.push_back(AccessRecord { range: r, write: false, h2d_bytes: out.h2d_bytes, wrapped });
        if window.len() > cfg.window {
            window.pop_front();
        }
        tracker.update(classify(&window), cfg.hysteresis);
        resident[r.start as usize..r.end as usize].fill(true);
        // -- oracle: the PR 2 rule + residency-aware byte count -------
        if let Some(want) = heuristic_prediction(tracker.current(), r, cfg.max_predict_pages) {
            let want = PageRange::new(want.start.min(n_pages), want.end.min(n_pages));
            for slot in resident[want.start as usize..want.end as usize].iter_mut() {
                if !*slot {
                    *slot = true;
                    expected_total += PAGE_SIZE;
                }
            }
        }

        assert_eq!(
            rt.metrics.auto_prefetched_bytes, expected_total,
            "engine diverged from the classifier-rule oracle at access {r:?}"
        );
    }
    assert!(expected_total > 0, "oracle sanity: the stream must trigger predictions");
    // Heuristic mode never touches the learned-predictor machinery.
    assert_eq!(rt.metrics.auto_predict_queries, 0);
    assert_eq!(rt.metrics.auto_predict_confident, 0);
    assert_eq!(rt.metrics.auto_learned_predictions, 0);
    assert_eq!(rt.metrics.auto_fallback_predictions, 0);
    rt.check_residency_invariant().unwrap();
}

#[test]
fn heuristic_mode_is_deterministic() {
    let run = || {
        let (mut rt, id, _) = prepped(PredictorKind::Heuristic);
        let mut t = Ns::ZERO;
        for r in mixed_stream() {
            t = rt.gpu_access(id, r, false, t).done;
        }
        (t, rt.metrics)
    };
    let (t1, m1) = run();
    let (t2, m2) = run();
    assert_eq!(t1, t2);
    assert_eq!(m1, m2, "bit-identical across runs");
}

#[test]
fn learned_covers_an_irregular_cycle_the_classifier_cannot() {
    // Pointer-chase: repeating irregular deltas (+7, +13, +12 pages,
    // 4-page accesses). No majority stride -> the classifier says
    // Random and the heuristic engine never predicts; the delta tables
    // learn the cycle and the engine starts hitting.
    let stream: Vec<PageRange> = {
        let mut s = Vec::new();
        let mut start = 0u32;
        for i in 0..30 {
            s.push(PageRange::new(start, start + 4));
            start += [7u32, 13, 12][i % 3];
        }
        s
    };
    let run = |kind: PredictorKind| {
        let (mut rt, id, _) = prepped(kind);
        let mut t = Ns::ZERO;
        for &r in &stream {
            t = rt.gpu_access(id, r, false, t).done;
        }
        rt.metrics
    };
    let heur = run(PredictorKind::Heuristic);
    let learn = run(PredictorKind::Learned);
    assert_eq!(heur.auto_prefetched_bytes, 0, "classifier: Random, no predictions");
    assert!(learn.auto_prefetched_bytes > 0, "tables learned the cycle");
    assert!(
        learn.auto_prefetch_hit_bytes > 0,
        "learned predictions were consumed: {learn:?}"
    );
    assert!(learn.prediction_coverage() > 0.3, "coverage {}", learn.prediction_coverage());
}

#[test]
fn learned_hit_rate_not_worse_on_regular_streams() {
    // On the patterns the classifier already handles, the learned mode
    // (with its heuristic fallback) must not lose prefetch coverage.
    for (stride, len) in [(32u32, 32u32), (64, 16)] {
        let stream: Vec<PageRange> =
            (0..12).map(|i| PageRange::new(i * stride, i * stride + len)).collect();
        let run = |kind: PredictorKind| {
            let (mut rt, id, _) = prepped(kind);
            let mut t = Ns::ZERO;
            for &r in &stream {
                t = rt.gpu_access(id, r, false, t).done;
            }
            rt.metrics
        };
        let heur = run(PredictorKind::Heuristic);
        let learn = run(PredictorKind::Learned);
        assert!(
            learn.auto_prefetch_hit_bytes >= heur.auto_prefetch_hit_bytes,
            "stride {stride}: learned hit {} < heuristic hit {}",
            learn.auto_prefetch_hit_bytes,
            heur.auto_prefetch_hit_bytes,
        );
    }
}

#[test]
fn run_cell_plumbing_selects_the_predictor() {
    let cell = Cell {
        app: AppId::Bs,
        platform: PlatformId::IntelPascal,
        variant: Variant::UmAuto,
        regime: Regime::InMemory,
    };
    let mut plat = cell.platform.spec();
    plat.um.auto_predictor = PredictorKind::Heuristic;
    let r = run_cell_on(cell, 1, false, &plat);
    assert_eq!(r.last.metrics.auto_predict_queries, 0, "heuristic cell: tables untouched");
    let r = run_cell(cell, 1, false);
    assert!(r.last.metrics.auto_predict_queries > 0, "default (learned) cell consults them");
}

#[test]
fn both_predictor_modes_run_every_app() {
    for kind in [PredictorKind::Heuristic, PredictorKind::Learned] {
        let mut plat = PlatformId::IntelPascal.spec();
        plat.um.auto_predictor = kind;
        for app in AppId::ALL {
            let r = app.build(64 * MIB).run(&plat, Variant::UmAuto, false);
            assert!(r.kernel_time > Ns::ZERO, "{} ({})", app.name(), kind.name());
            assert!(r.metrics.auto_decisions > 0, "{} ({})", app.name(), kind.name());
        }
    }
}
