//! Property-based tests (util::quick, DESIGN.md §2 substitutions):
//! random operation sequences against the UM runtime must preserve the
//! core invariants regardless of platform, sizes, advises or order.

use umbra::mem::{
    AdviseFlags, AllocId, PageFlags, PageRange, PageState, PageTable, Residency, PAGE_SIZE,
};
use umbra::platform::{PlatformId};
use umbra::quick_assert;
use umbra::um::{Advise, Loc, UmRuntime};
use umbra::util::quick::{forall, Gen};
use umbra::util::units::{Ns, MIB};

/// One random operation.
#[derive(Clone, Copy, Debug)]
enum Op {
    HostAccess { write: bool },
    GpuAccess { write: bool },
    Advise(u8),
    PrefetchGpu,
    PrefetchCpu,
}

fn random_op(g: &mut Gen) -> Op {
    match g.u64(0, 5) {
        0 => Op::HostAccess { write: g.bool() },
        1 | 2 => Op::GpuAccess { write: g.bool() }, // GPU-heavy mix
        3 => Op::Advise(g.u64(0, 5) as u8),
        4 => Op::PrefetchGpu,
        _ => Op::PrefetchCpu,
    }
}

fn advise_of(code: u8) -> Advise {
    match code {
        0 => Advise::ReadMostly,
        1 => Advise::PreferredLocation(Loc::Gpu),
        2 => Advise::PreferredLocation(Loc::Cpu),
        3 => Advise::AccessedBy(Loc::Cpu),
        4 => Advise::AccessedBy(Loc::Gpu),
        _ => Advise::UnsetPreferredLocation,
    }
}

/// Build a runtime with a shrunken device so oversubscription paths
/// fire often, plus 1-3 allocations of random sizes.
fn random_runtime(g: &mut Gen) -> (UmRuntime, Vec<AllocId>) {
    let plat_id = g.pick(&[PlatformId::IntelPascal, PlatformId::IntelVolta, PlatformId::P9Volta]);
    let mut plat = plat_id.spec();
    plat.gpu.mem_capacity = g.u64(32, 128) * MIB;
    plat.gpu.reserved = 0;
    let mut r = UmRuntime::new(&plat);
    let n_allocs = g.usize(1, 3);
    let ids = (0..n_allocs)
        .map(|i| {
            let size = g.u64(1, 96) * MIB;
            r.malloc_managed(&format!("a{i}"), size)
        })
        .collect();
    (r, ids)
}

fn random_range(g: &mut Gen, r: &UmRuntime, id: AllocId) -> PageRange {
    let n = r.space.get(id).n_pages();
    let start = g.u64(0, n as u64 - 1) as u32;
    let len = g.u64(1, (n - start) as u64) as u32;
    PageRange::new(start, start + len)
}

#[test]
fn residency_invariant_under_random_ops() {
    forall("residency-invariant", 60, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 30) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
            if let Err(e) = r.check_residency_invariant() {
                return Err(format!("after op: {e}"));
            }
            quick_assert!(r.dev.used() <= r.dev.capacity(), "over capacity");
        }
        Ok(())
    });
}

#[test]
fn time_never_goes_backwards() {
    forall("monotone-time", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 25) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            let done = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            };
            quick_assert!(done >= now, "op completed before it started: {done:?} < {now:?}");
            now = done;
        }
        Ok(())
    });
}

#[test]
fn byte_conservation_migrations_match_metrics() {
    // Every migrated/prefetched page is PAGE_SIZE bytes in the h2d/d2h
    // byte counters (no bytes invented or lost).
    forall("byte-conservation", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 25) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
        }
        let m = &r.metrics;
        let h2d_pages = m.migrated_pages_h2d + m.prefetched_pages_h2d;
        quick_assert!(
            m.h2d_bytes == h2d_pages * PAGE_SIZE,
            "h2d bytes {} != pages {} * {}",
            m.h2d_bytes,
            h2d_pages,
            PAGE_SIZE
        );
        let d2h_pages = m.migrated_pages_d2h + m.prefetched_pages_d2h;
        quick_assert!(
            m.d2h_bytes == d2h_pages * PAGE_SIZE + m.writeback_bytes,
            "d2h bytes {} != pages {} * {} + writeback {}",
            m.d2h_bytes,
            d2h_pages,
            PAGE_SIZE,
            m.writeback_bytes
        );
        Ok(())
    });
}

#[test]
fn no_page_is_both_dirty_and_duplicated() {
    // A ReadMostly duplicate (residency Both) is by construction clean:
    // any write collapses it first.
    forall("dirty-xor-duplicated", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 30) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
            for alloc in r.space.iter() {
                let bad = alloc.pages.count(alloc.full(), |p| {
                    p.residency == Residency::Both
                        && p.flags.get(umbra::mem::PageFlags::DIRTY)
                });
                quick_assert!(bad == 0, "alloc {} has {bad} dirty duplicates", alloc.name);
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Differential test: interval page table vs. naive flat-vec reference.
// ---------------------------------------------------------------------

/// Naive O(pages) reference model with the semantics the flat
/// `Vec<PageState>` table had before the interval refactor.
struct FlatTable {
    pages: Vec<PageState>,
}

impl FlatTable {
    fn new(n: u32) -> FlatTable {
        FlatTable { pages: vec![PageState::default(); n as usize] }
    }
    fn clamp(&self, r: PageRange) -> PageRange {
        let n = self.pages.len() as u32;
        PageRange::new(r.start.min(n), r.end.min(n))
    }
    fn update(&mut self, r: PageRange, mut f: impl FnMut(&mut PageState)) {
        let r = self.clamp(r);
        for i in r.start..r.end {
            f(&mut self.pages[i as usize]);
        }
    }
    fn set_range(&mut self, r: PageRange, s: PageState) {
        self.update(r, |p| *p = s);
    }
    fn count(&self, r: PageRange, mut pred: impl FnMut(&PageState) -> bool) -> u32 {
        let r = self.clamp(r);
        (r.start..r.end).filter(|&i| pred(&self.pages[i as usize])).count() as u32
    }
    /// The old per-page run-splitting algorithm, keyed on residency.
    fn runs_residency(&self, r: PageRange) -> Vec<(PageRange, Residency)> {
        let r = self.clamp(r);
        let mut out = Vec::new();
        if r.is_empty() {
            return out;
        }
        let mut start = r.start;
        let mut class = self.pages[r.start as usize].residency;
        for i in r.start + 1..r.end {
            let c = self.pages[i as usize].residency;
            if c != class {
                out.push((PageRange::new(start, i), class));
                start = i;
                class = c;
            }
        }
        out.push((PageRange::new(start, r.end), class));
        out
    }
}

fn random_state(g: &mut Gen) -> PageState {
    PageState {
        residency: match g.u64(0, 3) {
            0 => Residency::Unmapped,
            1 => Residency::Host,
            2 => Residency::Device,
            _ => Residency::Both,
        },
        flags: PageFlags(g.u64(0, 15) as u8),
        advise: AdviseFlags(g.u64(0, 31) as u8),
    }
}

fn random_prange(g: &mut Gen, n: u32) -> PageRange {
    let start = g.u64(0, n as u64) as u32;
    let end = g.u64(start as u64, n as u64) as u32;
    PageRange::new(start, end)
}

#[test]
fn interval_table_matches_flat_reference_model() {
    // Acceptance gate: ≥ 1000 random operation sequences, each mixing
    // the op shapes the UM layer issues (bulk overwrite = migrate /
    // reset, masked flag transform = advise, conditional transform =
    // fault / invalidation, single-page write = get_mut).
    forall("interval-vs-flat", 1000, |g| {
        let n = g.u64(1, 384) as u32;
        let mut it = PageTable::new(n);
        let mut ft = FlatTable::new(n);
        for _ in 0..g.usize(1, 24) {
            let r = random_prange(g, n);
            match g.u64(0, 3) {
                0 => {
                    let s = random_state(g);
                    it.set_range(r, s);
                    ft.set_range(r, s);
                }
                1 => {
                    let bit = [
                        PageFlags::DIRTY,
                        PageFlags::CPU_MAPPED,
                        PageFlags::GPU_MAPPED,
                        PageFlags::POPULATED,
                    ][g.usize(0, 3)];
                    let on = g.bool();
                    it.update(r, |p| p.flags.set(bit, on));
                    ft.update(r, |p| p.flags.set(bit, on));
                }
                2 => {
                    let from = random_state(g).residency;
                    let to = random_state(g).residency;
                    let xform = move |p: &mut PageState| {
                        if p.residency == from {
                            p.residency = to;
                            p.flags.set(PageFlags::DIRTY, to == Residency::Device);
                        }
                    };
                    it.update(r, xform);
                    ft.update(r, xform);
                }
                _ => {
                    let idx = g.u64(0, n as u64 - 1) as u32;
                    let s = random_state(g);
                    *it.get_mut(idx) = s;
                    ft.pages[idx as usize] = s;
                }
            }
            // Observable state must agree after every op.
            let probe = random_prange(g, n);
            let res = random_state(g).residency;
            quick_assert!(
                it.count(probe, |p| p.residency == res)
                    == ft.count(probe, |p| p.residency == res),
                "count diverged on {probe:?}"
            );
            let ir: Vec<_> = it.runs(probe, |p| p.residency).collect();
            let fr = ft.runs_residency(probe);
            quick_assert!(ir == fr, "runs diverged on {probe:?}: {ir:?} vs {fr:?}");
        }
        for i in 0..n {
            quick_assert!(*it.get(i) == ft.pages[i as usize], "page {i} state diverged");
        }
        quick_assert!(
            it.segment_count() <= n as usize,
            "more segments than pages: {} > {n}",
            it.segment_count()
        );
        Ok(())
    });
}

#[test]
fn determinism_same_seed_same_simulation() {
    forall("determinism", 15, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let run = |seed: u64| {
            let mut g2 = Gen::new(seed);
            let (mut r, ids) = random_runtime(&mut g2);
            let mut now = Ns::ZERO;
            for _ in 0..20 {
                let id = g2.pick(&ids);
                let range = random_range(&mut g2, &r, id);
                now = match random_op(&mut g2) {
                    Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                    Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                    Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                    Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                    Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
                }
                .max(now);
            }
            (now, r.metrics)
        };
        let (t1, m1) = run(seed);
        let (t2, m2) = run(seed);
        quick_assert!(t1 == t2 && m1 == m2, "simulation not deterministic for seed {seed}");
        Ok(())
    });
}
