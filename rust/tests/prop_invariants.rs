//! Property-based tests (util::quick, DESIGN.md §2 substitutions):
//! random operation sequences against the UM runtime must preserve the
//! core invariants regardless of platform, sizes, advises or order.

use umbra::mem::{
    AdviseFlags, AllocId, PageFlags, PageRange, PageState, PageTable, Residency, PAGE_SIZE,
};
use umbra::platform::{PlatformId};
use umbra::quick_assert;
use umbra::um::{Advise, Loc, UmRuntime};
use umbra::util::quick::{forall, Gen};
use umbra::util::units::{Ns, MIB};

/// One random operation.
#[derive(Clone, Copy, Debug)]
enum Op {
    HostAccess { write: bool },
    GpuAccess { write: bool },
    Advise(u8),
    PrefetchGpu,
    PrefetchCpu,
}

fn random_op(g: &mut Gen) -> Op {
    match g.u64(0, 5) {
        0 => Op::HostAccess { write: g.bool() },
        1 | 2 => Op::GpuAccess { write: g.bool() }, // GPU-heavy mix
        3 => Op::Advise(g.u64(0, 5) as u8),
        4 => Op::PrefetchGpu,
        _ => Op::PrefetchCpu,
    }
}

fn advise_of(code: u8) -> Advise {
    match code {
        0 => Advise::ReadMostly,
        1 => Advise::PreferredLocation(Loc::Gpu),
        2 => Advise::PreferredLocation(Loc::Cpu),
        3 => Advise::AccessedBy(Loc::Cpu),
        4 => Advise::AccessedBy(Loc::Gpu),
        _ => Advise::UnsetPreferredLocation,
    }
}

/// Build a runtime with a shrunken device so oversubscription paths
/// fire often, plus 1-3 allocations of random sizes.
fn random_runtime(g: &mut Gen) -> (UmRuntime, Vec<AllocId>) {
    // All four spec platforms: the generic invariants must hold in the
    // coherent (counter-migration) regime exactly as in the
    // fault-driven one.
    let plat_id = g.pick(&[
        PlatformId::IntelPascal,
        PlatformId::IntelVolta,
        PlatformId::P9Volta,
        PlatformId::GraceCoherent,
    ]);
    let mut plat = plat_id.spec();
    plat.gpu.mem_capacity = g.u64(32, 128) * MIB;
    plat.gpu.reserved = 0;
    let mut r = UmRuntime::new(&plat);
    let n_allocs = g.usize(1, 3);
    let ids = (0..n_allocs)
        .map(|i| {
            let size = g.u64(1, 96) * MIB;
            r.malloc_managed(&format!("a{i}"), size)
        })
        .collect();
    (r, ids)
}

fn random_range(g: &mut Gen, r: &UmRuntime, id: AllocId) -> PageRange {
    let n = r.space.get(id).n_pages();
    let start = g.u64(0, n as u64 - 1) as u32;
    let len = g.u64(1, (n - start) as u64) as u32;
    PageRange::new(start, start + len)
}

#[test]
fn residency_invariant_under_random_ops() {
    forall("residency-invariant", 60, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 30) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
            if let Err(e) = r.check_residency_invariant() {
                return Err(format!("after op: {e}"));
            }
            quick_assert!(r.dev.used() <= r.dev.capacity(), "over capacity");
        }
        Ok(())
    });
}

#[test]
fn time_never_goes_backwards() {
    forall("monotone-time", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 25) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            let done = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            };
            quick_assert!(done >= now, "op completed before it started: {done:?} < {now:?}");
            now = done;
        }
        Ok(())
    });
}

#[test]
fn byte_conservation_migrations_match_metrics() {
    // Every migrated/prefetched page is PAGE_SIZE bytes in the h2d/d2h
    // byte counters (no bytes invented or lost).
    forall("byte-conservation", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 25) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
        }
        let m = &r.metrics;
        let h2d_pages = m.migrated_pages_h2d + m.prefetched_pages_h2d;
        quick_assert!(
            m.h2d_bytes == h2d_pages * PAGE_SIZE,
            "h2d bytes {} != pages {} * {}",
            m.h2d_bytes,
            h2d_pages,
            PAGE_SIZE
        );
        let d2h_pages = m.migrated_pages_d2h + m.prefetched_pages_d2h;
        quick_assert!(
            m.d2h_bytes == d2h_pages * PAGE_SIZE + m.writeback_bytes,
            "d2h bytes {} != pages {} * {} + writeback {}",
            m.d2h_bytes,
            d2h_pages,
            PAGE_SIZE,
            m.writeback_bytes
        );
        Ok(())
    });
}

#[test]
fn no_page_is_both_dirty_and_duplicated() {
    // A ReadMostly duplicate (residency Both) is by construction clean:
    // any write collapses it first.
    forall("dirty-xor-duplicated", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 30) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
            for alloc in r.space.iter() {
                let bad = alloc.pages.count(alloc.full(), |p| {
                    p.residency == Residency::Both
                        && p.flags.get(umbra::mem::PageFlags::DIRTY)
                });
                quick_assert!(bad == 0, "alloc {} has {bad} dirty duplicates", alloc.name);
            }
        }
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Differential test: interval page table vs. naive flat-vec reference.
// ---------------------------------------------------------------------

/// Naive O(pages) reference model with the semantics the flat
/// `Vec<PageState>` table had before the interval refactor.
struct FlatTable {
    pages: Vec<PageState>,
}

impl FlatTable {
    fn new(n: u32) -> FlatTable {
        FlatTable { pages: vec![PageState::default(); n as usize] }
    }
    fn clamp(&self, r: PageRange) -> PageRange {
        let n = self.pages.len() as u32;
        PageRange::new(r.start.min(n), r.end.min(n))
    }
    fn update(&mut self, r: PageRange, mut f: impl FnMut(&mut PageState)) {
        let r = self.clamp(r);
        for i in r.start..r.end {
            f(&mut self.pages[i as usize]);
        }
    }
    fn set_range(&mut self, r: PageRange, s: PageState) {
        self.update(r, |p| *p = s);
    }
    fn count(&self, r: PageRange, mut pred: impl FnMut(&PageState) -> bool) -> u32 {
        let r = self.clamp(r);
        (r.start..r.end).filter(|&i| pred(&self.pages[i as usize])).count() as u32
    }
    /// The old per-page run-splitting algorithm, keyed on residency.
    fn runs_residency(&self, r: PageRange) -> Vec<(PageRange, Residency)> {
        let r = self.clamp(r);
        let mut out = Vec::new();
        if r.is_empty() {
            return out;
        }
        let mut start = r.start;
        let mut class = self.pages[r.start as usize].residency;
        for i in r.start + 1..r.end {
            let c = self.pages[i as usize].residency;
            if c != class {
                out.push((PageRange::new(start, i), class));
                start = i;
                class = c;
            }
        }
        out.push((PageRange::new(start, r.end), class));
        out
    }
}

fn random_state(g: &mut Gen) -> PageState {
    PageState {
        residency: match g.u64(0, 3) {
            0 => Residency::Unmapped,
            1 => Residency::Host,
            2 => Residency::Device,
            _ => Residency::Both,
        },
        flags: PageFlags(g.u64(0, 15) as u8),
        advise: AdviseFlags(g.u64(0, 31) as u8),
    }
}

fn random_prange(g: &mut Gen, n: u32) -> PageRange {
    let start = g.u64(0, n as u64) as u32;
    let end = g.u64(start as u64, n as u64) as u32;
    PageRange::new(start, end)
}

#[test]
fn interval_table_matches_flat_reference_model() {
    // Acceptance gate: ≥ 1000 random operation sequences, each mixing
    // the op shapes the UM layer issues (bulk overwrite = migrate /
    // reset, masked flag transform = advise, conditional transform =
    // fault / invalidation, single-page write = get_mut).
    forall("interval-vs-flat", 1000, |g| {
        let n = g.u64(1, 384) as u32;
        let mut it = PageTable::new(n);
        let mut ft = FlatTable::new(n);
        for _ in 0..g.usize(1, 24) {
            let r = random_prange(g, n);
            match g.u64(0, 3) {
                0 => {
                    let s = random_state(g);
                    it.set_range(r, s);
                    ft.set_range(r, s);
                }
                1 => {
                    let bit = [
                        PageFlags::DIRTY,
                        PageFlags::CPU_MAPPED,
                        PageFlags::GPU_MAPPED,
                        PageFlags::POPULATED,
                    ][g.usize(0, 3)];
                    let on = g.bool();
                    it.update(r, |p| p.flags.set(bit, on));
                    ft.update(r, |p| p.flags.set(bit, on));
                }
                2 => {
                    let from = random_state(g).residency;
                    let to = random_state(g).residency;
                    let xform = move |p: &mut PageState| {
                        if p.residency == from {
                            p.residency = to;
                            p.flags.set(PageFlags::DIRTY, to == Residency::Device);
                        }
                    };
                    it.update(r, xform);
                    ft.update(r, xform);
                }
                _ => {
                    let idx = g.u64(0, n as u64 - 1) as u32;
                    let s = random_state(g);
                    *it.get_mut(idx) = s;
                    ft.pages[idx as usize] = s;
                }
            }
            // Observable state must agree after every op.
            let probe = random_prange(g, n);
            let res = random_state(g).residency;
            quick_assert!(
                it.count(probe, |p| p.residency == res)
                    == ft.count(probe, |p| p.residency == res),
                "count diverged on {probe:?}"
            );
            let ir: Vec<_> = it.runs(probe, |p| p.residency).collect();
            let fr = ft.runs_residency(probe);
            quick_assert!(ir == fr, "runs diverged on {probe:?}: {ir:?} vs {fr:?}");
        }
        for i in 0..n {
            quick_assert!(*it.get(i) == ft.pages[i as usize], "page {i} state diverged");
        }
        quick_assert!(
            it.segment_count() <= n as usize,
            "more segments than pages: {} > {n}",
            it.segment_count()
        );
        Ok(())
    });
}

// ---------------------------------------------------------------------
// Differential test: coherent access counters vs. a naive per-group
// reference model (docs/PLATFORMS.md).
// ---------------------------------------------------------------------

/// Naive reference for the Grace-class access-counter machinery: flat
/// per-page residency plus one touch counter per page group. Mirrors
/// the documented contract — one touch per overlapping group per
/// serviced host-resident run; a crossing the instant a counter equals
/// the threshold; migration of run ∩ group while at-or-above it.
struct CounterRef {
    gp: u32,
    threshold: u32,
    on_device: Vec<bool>,
    touches: Vec<u32>,
    crossings: u64,
    migrations: u64,
    migrated_pages: u64,
    remote_bytes: u64,
    touched: Vec<bool>,
}

impl CounterRef {
    fn new(n_pages: u32, gp: u32, threshold: u32) -> CounterRef {
        let n_groups = n_pages.div_ceil(gp);
        CounterRef {
            gp,
            threshold,
            on_device: vec![false; n_pages as usize],
            touches: vec![0; n_groups as usize],
            crossings: 0,
            migrations: 0,
            migrated_pages: 0,
            remote_bytes: 0,
            touched: vec![false; n_pages as usize],
        }
    }

    /// One GPU access over `range`: split into maximal host-resident
    /// runs, service each remotely, bump counters, migrate hot extents.
    fn gpu_access(&mut self, range: PageRange) {
        for p in range.start..range.end {
            self.touched[p as usize] = true;
        }
        let mut pos = range.start;
        while pos < range.end {
            if self.on_device[pos as usize] {
                pos += 1;
                continue;
            }
            let mut end = pos;
            while end < range.end && !self.on_device[end as usize] {
                end += 1;
            }
            self.remote_bytes += PageRange::new(pos, end).bytes();
            for gi in pos / self.gp..=(end - 1) / self.gp {
                let t = &mut self.touches[gi as usize];
                *t += 1;
                if *t == self.threshold {
                    self.crossings += 1;
                }
                if *t >= self.threshold {
                    let s = pos.max(gi * self.gp);
                    let e = end.min((gi + 1) * self.gp);
                    self.migrations += 1;
                    self.migrated_pages += u64::from(e - s);
                    for p in s..e {
                        self.on_device[p as usize] = true;
                    }
                }
            }
            pos = end;
        }
    }

    fn touched_bytes(&self) -> u64 {
        self.touched.iter().filter(|&&t| t).count() as u64 * PAGE_SIZE
    }
}

/// A small in-capacity Grace runtime (no eviction pressure — the
/// reference model deliberately excludes it) with one host-initialized
/// managed allocation and randomized counter knobs.
fn grace_runtime(g: &mut Gen) -> (UmRuntime, AllocId, u32, u32) {
    let mut plat = PlatformId::GraceCoherent.spec();
    let gp = g.u64(1, 32) as u32;
    let threshold = g.u64(1, 6) as u32;
    plat.um.counter_group_pages = gp;
    plat.um.counter_threshold = threshold;
    let mut r = UmRuntime::new(&plat);
    let id = r.malloc_managed("a", g.u64(1, 24) * MIB);
    let full = r.space.get(id).full();
    let _ = r.host_access(id, full, true, Ns::ZERO);
    (r, id, gp, threshold)
}

#[test]
fn coherent_counters_match_naive_reference() {
    forall("coherent-counter-reference", 200, |g| {
        let (mut r, id, gp, threshold) = grace_runtime(g);
        let n = r.space.get(id).n_pages();
        let mut reference = CounterRef::new(n, gp, threshold);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(3, 40) {
            let range = random_range(g, &r, id);
            let write = g.bool();
            now = r.gpu_access(id, range, write, now).done.max(now);
            reference.gpu_access(range);
        }
        let m = &r.metrics;
        quick_assert!(m.gpu_fault_groups == 0, "coherent run took a fault group");
        quick_assert!(
            m.counter_threshold_crossings == reference.crossings,
            "crossings diverged: runtime {} vs reference {}",
            m.counter_threshold_crossings,
            reference.crossings
        );
        quick_assert!(
            m.counter_migrations == reference.migrations,
            "migrations diverged: runtime {} vs reference {}",
            m.counter_migrations,
            reference.migrations
        );
        quick_assert!(
            m.migrated_pages_h2d == reference.migrated_pages,
            "migrated pages diverged: runtime {} vs reference {}",
            m.migrated_pages_h2d,
            reference.migrated_pages
        );
        quick_assert!(
            m.remote_access_bytes == reference.remote_bytes,
            "remote bytes diverged: runtime {} vs reference {}",
            m.remote_access_bytes,
            reference.remote_bytes
        );
        // Migrated volume never exceeds what the GPU actually touched
        // (the counter path moves run ∩ group, never whole groups).
        quick_assert!(
            m.migrated_pages_h2d * PAGE_SIZE <= reference.touched_bytes(),
            "migrated {} B beyond the touched extent {} B",
            m.migrated_pages_h2d * PAGE_SIZE,
            reference.touched_bytes()
        );
        // Byte conservation holds in the counter-migration regime too.
        quick_assert!(
            m.h2d_bytes == (m.migrated_pages_h2d + m.prefetched_pages_h2d) * PAGE_SIZE,
            "h2d byte conservation broke"
        );
        Ok(())
    });
}

#[test]
fn coherent_counter_state_resets_exactly() {
    // `reset_run_state` must clear the access counters to the same
    // zero state a fresh runtime has: replaying the identical access
    // sequence after a reset reproduces the identical metrics —
    // residual touches would migrate earlier and shift every counter.
    forall("coherent-counter-reset", 60, |g| {
        let (mut r, id, _, _) = grace_runtime(g);
        let ranges: Vec<PageRange> =
            (0..g.usize(3, 25)).map(|_| random_range(g, &r, id)).collect();
        let run = |r: &mut UmRuntime| {
            let full = r.space.get(id).full();
            let mut now = r.host_access(id, full, true, Ns::ZERO).done;
            for &range in &ranges {
                now = r.gpu_access(id, range, false, now).done.max(now);
            }
            r.metrics
        };
        r.reset_run_state(); // discard the init from grace_runtime()
        let first = run(&mut r);
        r.reset_run_state();
        let second = run(&mut r);
        quick_assert!(
            first == second,
            "metrics diverged across reset_run_state: {first:?} vs {second:?}"
        );
        quick_assert!(
            first.counter_migrations > 0 || first.counter_threshold_crossings == 0,
            "a crossing without a migration is impossible"
        );
        Ok(())
    });
}

#[test]
fn determinism_same_seed_same_simulation() {
    forall("determinism", 15, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let run = |seed: u64| {
            let mut g2 = Gen::new(seed);
            let (mut r, ids) = random_runtime(&mut g2);
            let mut now = Ns::ZERO;
            for _ in 0..20 {
                let id = g2.pick(&ids);
                let range = random_range(&mut g2, &r, id);
                now = match random_op(&mut g2) {
                    Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                    Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                    Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                    Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                    Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
                }
                .max(now);
            }
            (now, r.metrics)
        };
        let (t1, m1) = run(seed);
        let (t2, m2) = run(seed);
        quick_assert!(t1 == t2 && m1 == m2, "simulation not deterministic for seed {seed}");
        Ok(())
    });
}
