//! Property-based tests (util::quick, DESIGN.md §2 substitutions):
//! random operation sequences against the UM runtime must preserve the
//! core invariants regardless of platform, sizes, advises or order.

use umbra::mem::{AllocId, PageRange, Residency, PAGE_SIZE};
use umbra::platform::{PlatformId};
use umbra::quick_assert;
use umbra::um::{Advise, Loc, UmRuntime};
use umbra::util::quick::{forall, Gen};
use umbra::util::units::{Ns, MIB};

/// One random operation.
#[derive(Clone, Copy, Debug)]
enum Op {
    HostAccess { write: bool },
    GpuAccess { write: bool },
    Advise(u8),
    PrefetchGpu,
    PrefetchCpu,
}

fn random_op(g: &mut Gen) -> Op {
    match g.u64(0, 5) {
        0 => Op::HostAccess { write: g.bool() },
        1 | 2 => Op::GpuAccess { write: g.bool() }, // GPU-heavy mix
        3 => Op::Advise(g.u64(0, 5) as u8),
        4 => Op::PrefetchGpu,
        _ => Op::PrefetchCpu,
    }
}

fn advise_of(code: u8) -> Advise {
    match code {
        0 => Advise::ReadMostly,
        1 => Advise::PreferredLocation(Loc::Gpu),
        2 => Advise::PreferredLocation(Loc::Cpu),
        3 => Advise::AccessedBy(Loc::Cpu),
        4 => Advise::AccessedBy(Loc::Gpu),
        _ => Advise::UnsetPreferredLocation,
    }
}

/// Build a runtime with a shrunken device so oversubscription paths
/// fire often, plus 1-3 allocations of random sizes.
fn random_runtime(g: &mut Gen) -> (UmRuntime, Vec<AllocId>) {
    let plat_id = g.pick(&[PlatformId::IntelPascal, PlatformId::IntelVolta, PlatformId::P9Volta]);
    let mut plat = plat_id.spec();
    plat.gpu.mem_capacity = g.u64(32, 128) * MIB;
    plat.gpu.reserved = 0;
    let mut r = UmRuntime::new(&plat);
    let n_allocs = g.usize(1, 3);
    let ids = (0..n_allocs)
        .map(|i| {
            let size = g.u64(1, 96) * MIB;
            r.malloc_managed(&format!("a{i}"), size)
        })
        .collect();
    (r, ids)
}

fn random_range(g: &mut Gen, r: &UmRuntime, id: AllocId) -> PageRange {
    let n = r.space.get(id).n_pages();
    let start = g.u64(0, n as u64 - 1) as u32;
    let len = g.u64(1, (n - start) as u64) as u32;
    PageRange::new(start, start + len)
}

#[test]
fn residency_invariant_under_random_ops() {
    forall("residency-invariant", 60, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 30) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
            if let Err(e) = r.check_residency_invariant() {
                return Err(format!("after op: {e}"));
            }
            quick_assert!(r.dev.used() <= r.dev.capacity(), "over capacity");
        }
        Ok(())
    });
}

#[test]
fn time_never_goes_backwards() {
    forall("monotone-time", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 25) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            let done = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            };
            quick_assert!(done >= now, "op completed before it started: {done:?} < {now:?}");
            now = done;
        }
        Ok(())
    });
}

#[test]
fn byte_conservation_migrations_match_metrics() {
    // Every migrated/prefetched page is PAGE_SIZE bytes in the h2d/d2h
    // byte counters (no bytes invented or lost).
    forall("byte-conservation", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 25) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
        }
        let m = &r.metrics;
        let h2d_pages = m.migrated_pages_h2d + m.prefetched_pages_h2d;
        quick_assert!(
            m.h2d_bytes == h2d_pages * PAGE_SIZE,
            "h2d bytes {} != pages {} * {}",
            m.h2d_bytes,
            h2d_pages,
            PAGE_SIZE
        );
        let d2h_pages = m.migrated_pages_d2h + m.prefetched_pages_d2h;
        quick_assert!(
            m.d2h_bytes == d2h_pages * PAGE_SIZE + m.writeback_bytes,
            "d2h bytes {} != pages {} * {} + writeback {}",
            m.d2h_bytes,
            d2h_pages,
            PAGE_SIZE,
            m.writeback_bytes
        );
        Ok(())
    });
}

#[test]
fn no_page_is_both_dirty_and_duplicated() {
    // A ReadMostly duplicate (residency Both) is by construction clean:
    // any write collapses it first.
    forall("dirty-xor-duplicated", 40, |g| {
        let (mut r, ids) = random_runtime(g);
        let mut now = Ns::ZERO;
        for _ in 0..g.usize(5, 30) {
            let id = g.pick(&ids);
            let range = random_range(g, &r, id);
            now = match random_op(g) {
                Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
            }
            .max(now);
            for alloc in r.space.iter() {
                let bad = alloc.pages.count(alloc.full(), |p| {
                    p.residency == Residency::Both
                        && p.flags.get(umbra::mem::PageFlags::DIRTY)
                });
                quick_assert!(bad == 0, "alloc {} has {bad} dirty duplicates", alloc.name);
            }
        }
        Ok(())
    });
}

#[test]
fn determinism_same_seed_same_simulation() {
    forall("determinism", 15, |g| {
        let seed = g.u64(0, u64::MAX - 1);
        let run = |seed: u64| {
            let mut g2 = Gen::new(seed);
            let (mut r, ids) = random_runtime(&mut g2);
            let mut now = Ns::ZERO;
            for _ in 0..20 {
                let id = g2.pick(&ids);
                let range = random_range(&mut g2, &r, id);
                now = match random_op(&mut g2) {
                    Op::HostAccess { write } => r.host_access(id, range, write, now).done,
                    Op::GpuAccess { write } => r.gpu_access(id, range, write, now).done,
                    Op::Advise(code) => r.mem_advise(id, range, advise_of(code), now),
                    Op::PrefetchGpu => r.prefetch_async(id, range, Loc::Gpu, now),
                    Op::PrefetchCpu => r.prefetch_async(id, range, Loc::Cpu, now),
                }
                .max(now);
            }
            (now, r.metrics)
        };
        let (t1, m1) = run(seed);
        let (t2, m2) = run(seed);
        quick_assert!(t1 == t2 && m1 == m2, "simulation not deterministic for seed {seed}");
        Ok(())
    });
}
