//! `cargo bench --bench auto_vs_tuned` — the um::auto policy-engine
//! study: `UM Auto` against basic UM and the best hand-tuned variant
//! per (platform, regime, app) cell, with the engine's decision
//! counters in the CSV.
use umbra::bench_harness::figures;

fn main() {
    let reps = std::env::var("UMBRA_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let t0 = std::time::Instant::now();
    let report = figures::fig_auto(reps);
    println!("{}", report.text);
    println!("auto_vs_tuned regenerated in {:?} ({} reps/cell)", t0.elapsed(), reps);
    report.write(std::path::Path::new("results")).expect("write results/");
}
