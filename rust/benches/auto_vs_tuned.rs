//! `cargo bench --bench auto_vs_tuned` — the um::auto policy-engine
//! studies: `UM Auto` against basic UM and the best hand-tuned variant
//! per (platform, regime, app) cell, plus the learned-vs-heuristic
//! predictor comparison, with the engine's decision counters in the
//! CSVs.
use umbra::bench_harness::figures;

fn main() {
    let reps = std::env::var("UMBRA_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(3);
    let t0 = std::time::Instant::now();
    let report = figures::fig_auto(reps);
    println!("{}", report.text);
    report.write(std::path::Path::new("results")).expect("write results/");
    let cmp = figures::fig_predictor(reps);
    println!("{}", cmp.text);
    cmp.write(std::path::Path::new("results")).expect("write results/");
    let ev = figures::fig_evict(reps);
    println!("{}", ev.text);
    ev.write(std::path::Path::new("results")).expect("write results/");
    println!(
        "auto_vs_tuned + predictor_vs_heuristic + evict_study regenerated in {:?} ({} reps/cell)",
        t0.elapsed(),
        reps
    );
}
