//! `cargo bench --bench ablations` — pre-eviction, fault-group size,
//! prefetch chunk, and advise-placement sweeps (DESIGN.md §4).
use umbra::bench_harness::ablate;

fn main() {
    let t0 = std::time::Instant::now();
    let report = ablate::ablate_all();
    println!("{}", report.text);
    println!("ablations regenerated in {:?}", t0.elapsed());
    report.write(std::path::Path::new("results")).expect("write results/");
}
