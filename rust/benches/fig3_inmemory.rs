//! `cargo bench --bench fig3_inmemory` — Fig. 3: in-memory GPU kernel
//! execution time, all apps x 5 variants x 3 platforms (5 reps each,
//! as in the paper). Prints the tables and writes results/fig3.*.
use umbra::bench_harness::figures;

fn main() {
    let reps = std::env::var("UMBRA_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let t0 = std::time::Instant::now();
    let report = figures::fig3(reps);
    println!("{}", report.text);
    println!("fig3 regenerated in {:?} ({} reps/cell)", t0.elapsed(), reps);
    report.write(std::path::Path::new("results")).expect("write results/");
}
