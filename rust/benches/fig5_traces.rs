//! `cargo bench --bench fig5_traces` — Fig. 5: in-memory UM transfer
//! time series (BS, CG x Intel-Pascal, P9-Volta), one CSV per panel.
use umbra::bench_harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let report = figures::fig5();
    println!("{}", report.text);
    println!("fig5 regenerated in {:?}", t0.elapsed());
    report.write(std::path::Path::new("results")).expect("write results/");
}
