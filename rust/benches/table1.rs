//! `cargo bench --bench table1` — regenerate Table I and time the
//! sizing machinery.
use umbra::bench_harness::{figures, BenchTimer};

fn main() {
    let mut t = BenchTimer::default();
    t.bench("table1/regenerate", || figures::table1());
    let report = figures::table1();
    println!("\n{}", report.text);
    report.write(std::path::Path::new("results")).expect("write results/");
}
