//! `cargo bench --bench fig4_breakdown` — Fig. 4: in-memory fault/
//! transfer time breakdown for BS and CG on Intel-Pascal + P9-Volta.
use umbra::bench_harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let report = figures::fig4();
    println!("{}", report.text);
    println!("fig4 regenerated in {:?}", t0.elapsed());
    report.write(std::path::Path::new("results")).expect("write results/");
}
