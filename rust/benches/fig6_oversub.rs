//! `cargo bench --bench fig6_oversub` — Fig. 6: oversubscribed GPU
//! kernel execution time (UM variants; no explicit baseline exists).
use umbra::bench_harness::figures;

fn main() {
    let reps = std::env::var("UMBRA_BENCH_REPS").ok().and_then(|s| s.parse().ok()).unwrap_or(5);
    let t0 = std::time::Instant::now();
    let report = figures::fig6(reps);
    println!("{}", report.text);
    println!("fig6 regenerated in {:?} ({} reps/cell)", t0.elapsed(), reps);
    report.write(std::path::Path::new("results")).expect("write results/");
}
