//! `cargo bench --bench fig8_traces` — Fig. 8: oversubscription UM
//! transfer time series (the paper's four panels).
use umbra::bench_harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let report = figures::fig8();
    println!("{}", report.text);
    println!("fig8 regenerated in {:?}", t0.elapsed());
    report.write(std::path::Path::new("results")).expect("write results/");
}
