//! `cargo bench --bench fig7_breakdown` — Fig. 7: oversubscription
//! breakdown — BS + CG on Intel-Pascal, BS + FDTD3d on P9-Volta.
use umbra::bench_harness::figures;

fn main() {
    let t0 = std::time::Instant::now();
    let report = figures::fig7();
    println!("{}", report.text);
    println!("fig7 regenerated in {:?}", t0.elapsed());
    report.write(std::path::Path::new("results")).expect("write results/");
}
