//! `cargo bench --bench micro_um` — microbenchmarks of the UM
//! simulator's hot paths (the L3 profiling targets of the §Perf pass):
//! fault-group assembly, migration, prefetch, eviction churn, and
//! end-to-end app simulation throughput.

use umbra::apps::{AppId, Regime, Variant};
use umbra::bench_harness::BenchTimer;
use umbra::platform::{intel_pascal, p9_volta, PlatformId};
use umbra::um::{Advise, Loc, UmRuntime};
use umbra::util::units::{Ns, GIB, MIB};

fn main() {
    let mut t = BenchTimer::default();

    // Fault-driven migration of 1 GiB (16384 pages).
    t.bench("um/migrate_1GiB_faulted", || {
        let mut r = UmRuntime::new(&intel_pascal());
        let id = r.malloc_managed("x", GIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.gpu_access(id, full, false, Ns::ZERO)
    });

    // Bulk prefetch of 1 GiB.
    t.bench("um/prefetch_1GiB_bulk", || {
        let mut r = UmRuntime::new(&intel_pascal());
        let id = r.malloc_managed("x", GIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.prefetch_async(id, full, Loc::Gpu, Ns::ZERO)
    });

    // Observer sliding window over a long fault stream (PR 4: the
    // window and the predictor's delta histories are rings — O(1)
    // pops, no Vec::remove(0) memmove per access on the fault path).
    t.bench("um/auto_observe_window_100k", || {
        use umbra::mem::PageRange;
        use umbra::um::auto::observer::AllocHistory;
        let mut h = AllocHistory::default();
        for i in 0..100_000u32 {
            let start = (i % 4096) * 16;
            h.observe(PageRange::new(start, start + 16), false, 0, 8, 4);
        }
        h.window().len()
    });

    // Eviction churn: cycle 2x capacity through a small device.
    t.bench("um/evict_churn_2x", || {
        let mut plat = intel_pascal();
        plat.gpu.mem_capacity = 256 * MIB;
        plat.gpu.reserved = 0;
        let mut r = UmRuntime::new(&plat);
        let a = r.malloc_managed("a", 256 * MIB);
        let b = r.malloc_managed("b", 256 * MIB);
        for id in [a, b] {
            let full = r.space.get(id).full();
            r.host_access(id, full, true, Ns::ZERO);
        }
        let fa = r.space.get(a).full();
        let fb = r.space.get(b).full();
        let mut now = Ns::ZERO;
        for _ in 0..4 {
            now = r.gpu_access(a, fa, false, now).done;
            now = r.gpu_access(b, fb, false, now).done;
        }
        r.dev.evictions
    });

    // Paper-scale (§IV) footprint: a 24 GiB managed allocation — 150%
    // of a 16 GiB device, 393216 pages of 64 KiB — through a full
    // advise + prefetch + reset repetition cycle. With the flat O(pages)
    // table every one of these steps walked ~393k PageState structs;
    // the interval table does O(runs) work per step.
    t.bench("um/advise_prefetch_reset_24GiB", || {
        let mut r = UmRuntime::new(&p9_volta());
        let id = r.malloc_managed("big", 24 * GIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.mem_advise(id, full, Advise::ReadMostly, Ns::ZERO);
        let done = r.prefetch_async(id, full, Loc::Gpu, Ns::ZERO);
        r.reset_run_state();
        done
    });

    // Oversubscribed cyclic thrash at paper scale: two 12 GiB
    // allocations alternately streamed through a 16 GiB device (PCIe
    // platform: every round migrates + evicts, the §IV-B pathology).
    t.bench("um/oversub_thrash_cyclic_24GiB", || {
        let mut plat = intel_pascal();
        plat.gpu.mem_capacity = 16 * GIB;
        plat.gpu.reserved = 0;
        let mut r = UmRuntime::new(&plat);
        let a = r.malloc_managed("a", 12 * GIB);
        let b = r.malloc_managed("b", 12 * GIB);
        for id in [a, b] {
            let full = r.space.get(id).full();
            r.host_access(id, full, true, Ns::ZERO);
        }
        let fa = r.space.get(a).full();
        let fb = r.space.get(b).full();
        let mut now = Ns::ZERO;
        for _ in 0..2 {
            now = r.gpu_access(a, fa, false, now).done;
            now = r.gpu_access(b, fb, false, now).done;
        }
        r.dev.evictions
    });

    // End-to-end app simulations (paper-scale footprints).
    for (app, plat, regime, variant, label) in [
        (AppId::Bs, PlatformId::IntelPascal, Regime::InMemory, Variant::Um, "app/bs_pascal_inmem_um"),
        (AppId::Bs, PlatformId::P9Volta, Regime::Oversubscribed, Variant::UmAdvise, "app/bs_p9_oversub_advise"),
        (AppId::Fdtd3d, PlatformId::P9Volta, Regime::Oversubscribed, Variant::UmAdvise, "app/fdtd_p9_oversub_advise"),
        (AppId::Cg, PlatformId::IntelPascal, Regime::Oversubscribed, Variant::Um, "app/cg_pascal_oversub_um"),
        (AppId::Graph500, PlatformId::IntelPascal, Regime::Oversubscribed, Variant::UmAdvise, "app/g500_pascal_oversub_advise"),
    ] {
        let a = app.build_for(plat, regime);
        let spec = plat.spec();
        t.bench(label, || a.run(&spec, variant, false).kernel_time);
    }
}
