//! `cargo bench --bench micro_um` — microbenchmarks of the UM
//! simulator's hot paths (the L3 profiling targets of the §Perf pass):
//! fault-group assembly, migration, prefetch, eviction churn, and
//! end-to-end app simulation throughput.

use umbra::apps::{AppId, Regime, Variant};
use umbra::bench_harness::BenchTimer;
use umbra::platform::{intel_pascal, PlatformId};
use umbra::um::{Loc, UmRuntime};
use umbra::util::units::{Ns, GIB, MIB};

fn main() {
    let mut t = BenchTimer::default();

    // Fault-driven migration of 1 GiB (16384 pages).
    t.bench("um/migrate_1GiB_faulted", || {
        let mut r = UmRuntime::new(&intel_pascal());
        let id = r.malloc_managed("x", GIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.gpu_access(id, full, false, Ns::ZERO)
    });

    // Bulk prefetch of 1 GiB.
    t.bench("um/prefetch_1GiB_bulk", || {
        let mut r = UmRuntime::new(&intel_pascal());
        let id = r.malloc_managed("x", GIB);
        let full = r.space.get(id).full();
        r.host_access(id, full, true, Ns::ZERO);
        r.prefetch_async(id, full, Loc::Gpu, Ns::ZERO)
    });

    // Eviction churn: cycle 2x capacity through a small device.
    t.bench("um/evict_churn_2x", || {
        let mut plat = intel_pascal();
        plat.gpu.mem_capacity = 256 * MIB;
        plat.gpu.reserved = 0;
        let mut r = UmRuntime::new(&plat);
        let a = r.malloc_managed("a", 256 * MIB);
        let b = r.malloc_managed("b", 256 * MIB);
        for id in [a, b] {
            let full = r.space.get(id).full();
            r.host_access(id, full, true, Ns::ZERO);
        }
        let fa = r.space.get(a).full();
        let fb = r.space.get(b).full();
        let mut now = Ns::ZERO;
        for _ in 0..4 {
            now = r.gpu_access(a, fa, false, now).done;
            now = r.gpu_access(b, fb, false, now).done;
        }
        r.dev.evictions
    });

    // End-to-end app simulations (paper-scale footprints).
    for (app, plat, regime, variant, label) in [
        (AppId::Bs, PlatformId::IntelPascal, Regime::InMemory, Variant::Um, "app/bs_pascal_inmem_um"),
        (AppId::Bs, PlatformId::P9Volta, Regime::Oversubscribed, Variant::UmAdvise, "app/bs_p9_oversub_advise"),
        (AppId::Fdtd3d, PlatformId::P9Volta, Regime::Oversubscribed, Variant::UmAdvise, "app/fdtd_p9_oversub_advise"),
        (AppId::Cg, PlatformId::IntelPascal, Regime::Oversubscribed, Variant::Um, "app/cg_pascal_oversub_um"),
        (AppId::Graph500, PlatformId::IntelPascal, Regime::Oversubscribed, Variant::UmAdvise, "app/g500_pascal_oversub_advise"),
    ] {
        let a = app.build_for(plat, regime);
        let spec = plat.spec();
        t.bench(label, || a.run(&spec, variant, false).kernel_time);
    }
}
